//! Health/stall watchdog: turns live telemetry into a three-state signal.
//!
//! The [`Watchdog`] is a pure observer on the training side: the event
//! hook calls [`Watchdog::note_step`] (two relaxed atomic stores — safe
//! in the allocation-free steady state), and failure paths call
//! [`Watchdog::mark_stalled`] with a sticky reason. The status server
//! calls [`Watchdog::evaluate`] on demand to fold the registry's signals
//! into a [`HealthState`]:
//!
//! * `Stalled` — a sticky failure was recorded (engine error, worker
//!   loss), or no step completed within the stall deadline. `/healthz`
//!   serves 503.
//! * `Degraded` — some worker's last step wall time exceeds
//!   `straggler_factor` × the median across workers, or the last
//!   correction norm blew past `correction_limit` (the divergence signal
//!   DC-S3GD monitors online). `/healthz` serves 503.
//! * `Healthy` — everything else. `/healthz` serves 200.
//!
//! State transitions append a typed [`HealthEvent`] to a bounded ring and
//! emit one warning line on stderr — groundwork for the future
//! `sgs daemon` / chaos suite, which will consume these events instead of
//! polling text.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use super::clock::WallClock;
use super::metrics::MetricsRegistry;

/// Tri-state health verdict, ordered by severity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthState {
    Healthy,
    Degraded,
    Stalled,
}

impl HealthState {
    pub fn as_str(&self) -> &'static str {
        match self {
            HealthState::Healthy => "healthy",
            HealthState::Degraded => "degraded",
            HealthState::Stalled => "stalled",
        }
    }

    /// HTTP status `/healthz` maps this state to.
    pub fn http_status(&self) -> u16 {
        match self {
            HealthState::Healthy => 200,
            HealthState::Degraded | HealthState::Stalled => 503,
        }
    }
}

/// Thresholds for [`Watchdog::evaluate`].
#[derive(Debug, Clone, Copy)]
pub struct HealthConfig {
    /// Seconds without a completed step before the run counts as stalled.
    pub stall_timeout_s: f64,
    /// A worker slower than this multiple of the median step time is a
    /// straggler (needs ≥ 2 live workers to define a median).
    pub straggler_factor: f64,
    /// `correction_max_last` above this is treated as divergence.
    pub correction_limit: f64,
}

impl Default for HealthConfig {
    fn default() -> HealthConfig {
        HealthConfig { stall_timeout_s: 60.0, straggler_factor: 4.0, correction_limit: 1e6 }
    }
}

/// One recorded state transition.
#[derive(Debug, Clone)]
pub struct HealthEvent {
    /// Microseconds since the watchdog started.
    pub t_us: u64,
    pub state: HealthState,
    pub reason: String,
}

const EVENT_RING: usize = 32;

/// See the module docs. Construction allocates; `note_step` never does.
#[derive(Debug)]
pub struct Watchdog {
    cfg: HealthConfig,
    clock: WallClock,
    last_iter: AtomicU64,
    /// `clock` microseconds when the last step was observed (watchdog
    /// start counts as step zero so a run that never steps still stalls).
    last_step_us: AtomicU64,
    stalled: AtomicBool,
    stalled_reason: Mutex<String>,
    last_state: Mutex<HealthState>,
    events: Mutex<Vec<HealthEvent>>,
}

impl Watchdog {
    pub fn new(cfg: HealthConfig) -> Watchdog {
        Watchdog {
            cfg,
            clock: WallClock::new(),
            last_iter: AtomicU64::new(0),
            last_step_us: AtomicU64::new(0),
            stalled: AtomicBool::new(false),
            stalled_reason: Mutex::new(String::new()),
            last_state: Mutex::new(HealthState::Healthy),
            events: Mutex::new(Vec::with_capacity(EVENT_RING)),
        }
    }

    /// Record step progress. Allocation-free: two relaxed atomic stores.
    pub fn note_step(&self, iter: u64) {
        self.last_iter.store(iter, Ordering::Relaxed);
        self.last_step_us.store(self.clock.now_us(), Ordering::Relaxed);
    }

    /// Latch a terminal failure (engine error, worker loss). Sticky: the
    /// watchdog reports `Stalled` from here on.
    pub fn mark_stalled(&self, reason: &str) {
        if !self.stalled.swap(true, Ordering::Relaxed) {
            if let Ok(mut r) = self.stalled_reason.lock() {
                r.clear();
                r.push_str(reason);
            }
        }
    }

    pub fn last_iter(&self) -> u64 {
        self.last_iter.load(Ordering::Relaxed)
    }

    /// Recorded state transitions, oldest first (bounded ring).
    pub fn events(&self) -> Vec<HealthEvent> {
        match self.events.lock() {
            Ok(g) => g.clone(),
            Err(p) => p.into_inner().clone(),
        }
    }

    /// Fold current signals into a verdict. Runs on the status-server /
    /// sampler monitor thread — allocation here is fine; only `note_step`
    /// sits on the training hot path.
    pub fn evaluate(&self, reg: &MetricsRegistry, workers: usize) -> (HealthState, String) {
        let verdict = self.judge(reg, workers);
        self.record_transition(&verdict);
        verdict
    }

    fn judge(&self, reg: &MetricsRegistry, workers: usize) -> (HealthState, String) {
        if self.stalled.load(Ordering::Relaxed) {
            let reason = match self.stalled_reason.lock() {
                Ok(g) => g.clone(),
                Err(p) => p.into_inner().clone(),
            };
            return (HealthState::Stalled, format!("run failed: {reason}"));
        }
        let idle_s = self
            .clock
            .now_us()
            .saturating_sub(self.last_step_us.load(Ordering::Relaxed)) as f64
            / 1e6;
        if idle_s > self.cfg.stall_timeout_s {
            return (
                HealthState::Stalled,
                format!(
                    "no step progress in {idle_s:.1}s (deadline {:.1}s)",
                    self.cfg.stall_timeout_s
                ),
            );
        }
        let correction = reg.gauge("correction_max_last").get();
        if !correction.is_nan() && (correction > self.cfg.correction_limit || correction.is_infinite())
        {
            return (
                HealthState::Degraded,
                format!(
                    "correction norm blowup: {correction:e} > limit {:e}",
                    self.cfg.correction_limit
                ),
            );
        }
        if workers >= 2 {
            let mut steps: Vec<(usize, f64)> = (0..workers)
                .map(|i| (i, reg.gauge(&format!("w{i}_step_wall_s")).get()))
                .filter(|(_, s)| s.is_finite() && *s > 0.0)
                .collect();
            if steps.len() >= 2 {
                let mut sorted: Vec<f64> = steps.iter().map(|(_, s)| *s).collect();
                sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
                let median = sorted[sorted.len() / 2];
                steps.sort_by(|a, b| {
                    b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal)
                });
                if let Some(&(worst, wall)) = steps.first() {
                    if median > 0.0 && wall > self.cfg.straggler_factor * median {
                        return (
                            HealthState::Degraded,
                            format!(
                                "worker {worst} straggling: step {wall:.3}s vs median \
                                 {median:.3}s (> {:.1}x)",
                                self.cfg.straggler_factor
                            ),
                        );
                    }
                }
            }
        }
        (HealthState::Healthy, String::from("ok"))
    }

    fn record_transition(&self, verdict: &(HealthState, String)) {
        let mut last = match self.last_state.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        if *last == verdict.0 {
            return;
        }
        *last = verdict.0;
        let ev = HealthEvent {
            t_us: self.clock.now_us(),
            state: verdict.0,
            reason: verdict.1.clone(),
        };
        if verdict.0 != HealthState::Healthy {
            eprintln!("sgs health: {} — {}", verdict.0.as_str(), verdict.1);
        }
        let mut events = match self.events.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        if events.len() == EVENT_RING {
            events.remove(0);
        }
        events.push(ev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn quick_cfg() -> HealthConfig {
        HealthConfig { stall_timeout_s: 1e6, ..HealthConfig::default() }
    }

    #[test]
    fn healthy_by_default_then_sticky_stall() {
        let reg = Arc::new(MetricsRegistry::new());
        let dog = Watchdog::new(quick_cfg());
        dog.note_step(1);
        let (state, _) = dog.evaluate(&reg, 0);
        assert_eq!(state, HealthState::Healthy);
        dog.mark_stalled("worker 1 connection reset");
        let (state, reason) = dog.evaluate(&reg, 0);
        assert_eq!(state, HealthState::Stalled);
        assert!(reason.contains("worker 1 connection reset"), "{reason}");
        assert_eq!(state.http_status(), 503);
        // transition recorded exactly once
        let events = dog.events();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].state, HealthState::Stalled);
        dog.evaluate(&reg, 0);
        assert_eq!(dog.events().len(), 1, "no duplicate transition events");
    }

    #[test]
    fn stall_deadline_without_steps() {
        let reg = Arc::new(MetricsRegistry::new());
        let dog = Watchdog::new(HealthConfig { stall_timeout_s: 0.0, ..HealthConfig::default() });
        std::thread::sleep(std::time::Duration::from_millis(2));
        let (state, reason) = dog.evaluate(&reg, 0);
        assert_eq!(state, HealthState::Stalled);
        assert!(reason.contains("no step progress"), "{reason}");
    }

    #[test]
    fn straggler_and_correction_degrade() {
        let reg = Arc::new(MetricsRegistry::new());
        let dog = Watchdog::new(quick_cfg());
        dog.note_step(3);
        reg.gauge("w0_step_wall_s").set(0.1);
        reg.gauge("w1_step_wall_s").set(0.1);
        reg.gauge("w2_step_wall_s").set(2.0);
        let (state, reason) = dog.evaluate(&reg, 3);
        assert_eq!(state, HealthState::Degraded);
        assert!(reason.contains("worker 2 straggling"), "{reason}");
        reg.gauge("w2_step_wall_s").set(0.1);
        let (state, _) = dog.evaluate(&reg, 3);
        assert_eq!(state, HealthState::Healthy, "recovers when the straggler catches up");
        reg.gauge("correction_max_last").set(1e9);
        let (state, reason) = dog.evaluate(&reg, 3);
        assert_eq!(state, HealthState::Degraded);
        assert!(reason.contains("correction norm blowup"), "{reason}");
    }

    #[test]
    fn single_worker_never_straggles() {
        let reg = Arc::new(MetricsRegistry::new());
        let dog = Watchdog::new(quick_cfg());
        dog.note_step(1);
        reg.gauge("w0_step_wall_s").set(50.0);
        assert_eq!(dog.evaluate(&reg, 1).0, HealthState::Healthy);
    }
}
