//! Observability: span tracing, metrics, and wall-clock access for all
//! three engines — zero external dependencies.
//!
//! * [`clock`] — the crate's only gateway to `Instant`/`SystemTime`
//!   (lint rule `det-wall-clock` bans them everywhere else outside
//!   tests); engines and transports hold [`WallClock`]/[`Deadline`]
//!   handles instead of naming the std types.
//! * [`span`] — phase spans ([`Phase`]: `fwd`, `bwd`, `opt`,
//!   `compensate`, `gossip`, `stash_wait`, `barrier`, `wire_tx/rx`, ...)
//!   recorded into the bounded, preallocated [`Tracer`]; dist workers
//!   stage theirs in an [`ObsBuffer`] and ship them over `Frame::Obs`.
//! * [`metrics`] — [`MetricsRegistry`] of counters/gauges/fixed-bucket
//!   histograms.
//! * [`trace`] — Chrome trace-event JSON export (Perfetto-loadable),
//!   written by `sgs train/launch --trace-out FILE`.
//! * [`report`] — the `sgs trace-report` analyzer: per-module/per-phase
//!   breakdowns, pipeline-fill vs steady-state split, bubble/straggler
//!   summary.
//! * [`timer`] — stopwatch + sampling helpers for benches and cost-model
//!   calibration (re-exported as `crate::util::timer`).
//! * [`prom`] — zero-dep Prometheus text-exposition encoder; the one
//!   formatter behind `/metrics` on both `sgs serve` and the training
//!   status server (`crate::monitor`), so the two planes emit
//!   byte-identical expositions.
//! * [`telemetry`] — [`TelemetrySampler`]: periodic registry snapshots
//!   (counters, gauges, histogram buckets + p50/p95/p99) into a bounded
//!   preallocated ring, encodable as JSONL for `--telemetry-out`.
//! * [`health`] — [`Watchdog`]: folds live signals into
//!   `Healthy | Degraded | Stalled` (`/healthz` 200 vs 503) — stall
//!   deadline, straggler detection, correction-norm blowup, sticky
//!   failure latch.
//!
//! # Contracts
//!
//! **Determinism (pure observer).** Attaching a tracer or registry never
//! changes what an engine computes: the sim engine's event stream and
//! final parameters are bit-identical with tracing on or off
//! (`rust/tests/obs_purity.rs`). Sim spans are synthesized from the
//! schedule and the *sim clock* — the deterministic engine never reads
//! real time.
//!
//! **Zero allocation after warmup.** Metric handles are registered once
//! at setup; every hot-path update is a relaxed atomic on preallocated
//! storage. Span buffers are preallocated and bounded: overflow drops
//! (and counts) spans instead of growing. `rust/tests/alloc_guard.rs`
//! pins steady-state steps at zero allocations with a registry attached.

pub mod clock;
pub mod health;
pub mod metrics;
pub mod prom;
pub mod report;
pub mod span;
pub mod telemetry;
pub mod timer;
pub mod trace;

pub use clock::{Deadline, WallClock};
pub use health::{HealthConfig, HealthEvent, HealthState, Watchdog};
pub use metrics::{quantile_from_buckets, Counter, Gauge, Histogram, MetricsRegistry};
pub use span::{ObsBuffer, Phase, Span, Tracer, DEFAULT_SPAN_CAPACITY, NO_COORD};
pub use telemetry::{TelemetrySampler, TelemetrySnapshot};
pub use trace::{chrome_trace_json, write_chrome_trace, TraceMeta};
