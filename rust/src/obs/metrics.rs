//! Counters, gauges, and fixed-bucket histograms behind a registry.
//!
//! The contract that lets engines update metrics from their hottest
//! loops: **registration allocates, updates never do**. A handle
//! (`Arc<Counter>` etc.) is obtained once at setup; every subsequent
//! `add`/`set`/`observe` is a handful of relaxed atomic operations on
//! preallocated storage — which is why `rust/tests/alloc_guard.rs` can
//! pin steady-state steps at zero allocations *with* a registry attached,
//! and why lint rule `hot-alloc` stays clean.
//!
//! Snapshots (`MetricsRegistry::to_json`) walk a `BTreeMap`, so exported
//! metric order is deterministic regardless of registration order.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;

/// Monotonically increasing event count.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-written value (f64 stored as bits in one atomic).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Fixed-bucket histogram: bucket `i` counts observations `<= bounds[i]`,
/// with one implicit overflow bucket above the last bound. The running
/// sum is kept in integer micro-units so `observe` stays a pure atomic
/// add (no CAS loop, no float atomics).
#[derive(Debug)]
pub struct Histogram {
    bounds: Box<[f64]>,
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum_micro: AtomicU64,
}

impl Histogram {
    /// `bounds` must be finite and strictly increasing; the storage for
    /// all buckets is allocated here, once.
    pub fn new(bounds: &[f64]) -> Histogram {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds not increasing");
        let buckets = (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect();
        Histogram {
            bounds: bounds.into(),
            buckets,
            count: AtomicU64::new(0),
            sum_micro: AtomicU64::new(0),
        }
    }

    /// Evenly spaced bounds over `[0, max]` (`n` finite buckets + overflow).
    pub fn linear(max: f64, n: usize) -> Histogram {
        let n = n.max(1);
        let bounds: Vec<f64> = (1..=n).map(|i| max * i as f64 / n as f64).collect();
        Histogram::new(&bounds)
    }

    pub fn observe(&self, v: f64) {
        let idx = self.bounds.partition_point(|&b| b < v);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let micro = if v.is_finite() && v > 0.0 { (v * 1e6) as u64 } else { 0 };
        self.sum_micro.fetch_add(micro, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of observations (micro-unit resolution).
    pub fn sum(&self) -> f64 {
        self.sum_micro.load(Ordering::Relaxed) as f64 / 1e6
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() / n as f64
        }
    }

    /// Per-bucket counts, overflow bucket last.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Copy per-bucket counts into a caller-owned slice (overflow bucket
    /// last) without allocating — the telemetry sampler's snapshot path.
    /// Slots beyond `out.len()` are dropped; slots beyond the bucket
    /// count are zeroed.
    pub fn bucket_counts_into(&self, out: &mut [u64]) {
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = match self.buckets.get(i) {
                Some(b) => b.load(Ordering::Relaxed),
                None => 0,
            };
        }
    }

    pub fn bounds(&self) -> &[f64] {
        &self.bounds
    }

    /// Interpolated quantile of the recorded distribution, `None` when
    /// the histogram is empty. See [`quantile_from_buckets`] for the
    /// interpolation rule. Allocates a transient count snapshot — use
    /// [`Histogram::bucket_counts_into`] + [`quantile_from_buckets`] on
    /// preallocated storage from allocation-free contexts.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        quantile_from_buckets(&self.bounds, &self.bucket_counts(), q)
    }
}

/// Estimate the `q`-quantile (`q` clamped to `[0, 1]`) of a fixed-bucket
/// histogram by linear interpolation inside the bucket holding the target
/// rank — the same estimate Prometheus' `histogram_quantile` computes.
///
/// `counts` holds per-bucket counts with the overflow bucket last (one
/// longer than `bounds`, shorter slices are treated as zero-padded).
/// Rules: an empty histogram (or empty `bounds`) yields `None`; the first
/// bucket's lower edge is `0.0` (or `bounds[0]` when that is negative);
/// a rank landing in the overflow bucket reports the last finite bound —
/// the distribution's tail is unbounded, so that is the honest floor.
pub fn quantile_from_buckets(bounds: &[f64], counts: &[u64], q: f64) -> Option<f64> {
    if bounds.is_empty() {
        return None;
    }
    let total: u64 = counts.iter().sum();
    if total == 0 {
        return None;
    }
    let rank = q.clamp(0.0, 1.0) * total as f64;
    let mut cumulative = 0u64;
    for (i, &bound) in bounds.iter().enumerate() {
        let in_bucket = counts.get(i).copied().unwrap_or(0);
        let next = cumulative + in_bucket;
        if (next as f64) >= rank && in_bucket > 0 {
            let lower = if i == 0 { bound.min(0.0) } else { bounds[i - 1] };
            let fraction = ((rank - cumulative as f64) / in_bucket as f64).clamp(0.0, 1.0);
            return Some(lower + fraction * (bound - lower));
        }
        cumulative = next;
    }
    // target rank sits in the overflow bucket
    bounds.last().copied()
}

/// Name → instrument registry shared by a session and its engine.
///
/// `counter`/`gauge`/`histogram` get-or-create: the first call allocates
/// the instrument, later calls (any thread) return the same handle.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        Arc::clone(
            lock(&self.counters).entry(name.to_string()).or_insert_with(Arc::default),
        )
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        Arc::clone(lock(&self.gauges).entry(name.to_string()).or_insert_with(Arc::default))
    }

    /// Get-or-create a histogram; `bounds` are used only on first
    /// creation (later callers share the existing buckets).
    pub fn histogram(&self, name: &str, bounds: &[f64]) -> Arc<Histogram> {
        Arc::clone(
            lock(&self.histograms)
                .entry(name.to_string())
                .or_insert_with(|| Arc::new(Histogram::new(bounds))),
        )
    }

    /// Apply one remote sample shipped over `Frame::Obs`: kind bytes per
    /// `crate::obs::span` (`METRIC_*` constants). Unknown kinds are
    /// ignored — a newer worker must not wedge an older coordinator.
    pub fn apply_sample(&self, name: &str, kind: u8, value: f64) {
        use crate::obs::span::{METRIC_COUNTER_ADD, METRIC_GAUGE_SET, METRIC_HISTOGRAM_OBSERVE};
        match kind {
            METRIC_COUNTER_ADD => self.counter(name).add(value.max(0.0) as u64),
            METRIC_GAUGE_SET => self.gauge(name).set(value),
            METRIC_HISTOGRAM_OBSERVE => {
                // remote histograms default to a decade of log-ish buckets;
                // local registrants that got there first keep their bounds
                self.histogram(name, &[0.001, 0.01, 0.1, 1.0, 10.0, 100.0]).observe(value)
            }
            _ => {}
        }
    }

    /// Look up a counter without creating it (read-only exporters use
    /// these `find_*` variants so a snapshot request can never register
    /// an instrument — notably a histogram with default bounds — before
    /// the owning loop does).
    pub fn find_counter(&self, name: &str) -> Option<Arc<Counter>> {
        lock(&self.counters).get(name).map(Arc::clone)
    }

    /// Look up a gauge without creating it.
    pub fn find_gauge(&self, name: &str) -> Option<Arc<Gauge>> {
        lock(&self.gauges).get(name).map(Arc::clone)
    }

    /// Look up a histogram without creating it.
    pub fn find_histogram(&self, name: &str) -> Option<Arc<Histogram>> {
        lock(&self.histograms).get(name).map(Arc::clone)
    }

    /// Every registered counter, name-sorted (BTreeMap order).
    pub fn counters(&self) -> Vec<(String, Arc<Counter>)> {
        lock(&self.counters).iter().map(|(n, c)| (n.clone(), Arc::clone(c))).collect()
    }

    /// Every registered gauge, name-sorted.
    pub fn gauges(&self) -> Vec<(String, Arc<Gauge>)> {
        lock(&self.gauges).iter().map(|(n, g)| (n.clone(), Arc::clone(g))).collect()
    }

    /// Every registered histogram, name-sorted.
    pub fn histograms(&self) -> Vec<(String, Arc<Histogram>)> {
        lock(&self.histograms).iter().map(|(n, h)| (n.clone(), Arc::clone(h))).collect()
    }

    /// `(counters, gauges, histograms)` cardinality — a cheap fingerprint
    /// the telemetry sampler polls to detect instruments registered after
    /// it resolved its handles (e.g. remote `w{i}_*` metrics arriving
    /// with the first `Frame::Obs`). Instruments are never removed, so
    /// equal counts mean an identical instrument set.
    pub fn instrument_counts(&self) -> (usize, usize, usize) {
        (
            lock(&self.counters).len(),
            lock(&self.gauges).len(),
            lock(&self.histograms).len(),
        )
    }

    /// Deterministically ordered snapshot of every instrument.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        let mut counters = Json::obj();
        for (name, c) in lock(&self.counters).iter() {
            counters.set(name, c.get());
        }
        let mut gauges = Json::obj();
        for (name, g) in lock(&self.gauges).iter() {
            gauges.set(name, g.get());
        }
        let mut hists = Json::obj();
        for (name, h) in lock(&self.histograms).iter() {
            let mut hj = Json::obj();
            hj.set("count", h.count())
                .set("sum", h.sum())
                .set("mean", h.mean())
                .set("bounds", h.bounds().to_vec())
                .set("buckets", h.bucket_counts().iter().map(|&c| c as usize).collect::<Vec<_>>());
            hists.set(name, hj);
        }
        j.set("counters", counters).set("gauges", gauges).set("histograms", hists);
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("iters_total");
        c.inc();
        c.add(4);
        assert_eq!(reg.counter("iters_total").get(), 5, "same handle by name");
        let g = reg.gauge("train_loss_last");
        g.set(2.25);
        assert_eq!(g.get(), 2.25);
    }

    #[test]
    fn histogram_buckets_and_overflow() {
        let h = Histogram::new(&[1.0, 2.0, 4.0]);
        for v in [0.5, 1.5, 3.0, 100.0] {
            h.observe(v);
        }
        assert_eq!(h.bucket_counts(), vec![1, 1, 1, 1]);
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 105.0).abs() < 1e-3);
        assert!((h.mean() - 26.25).abs() < 1e-3);
    }

    #[test]
    fn linear_bounds_cover_the_range() {
        let h = Histogram::linear(8.0, 4);
        assert_eq!(h.bounds(), &[2.0, 4.0, 6.0, 8.0]);
        h.observe(8.0); // on the last bound: counted, not overflow
        assert_eq!(h.bucket_counts(), vec![0, 0, 0, 1, 0]);
    }

    #[test]
    fn remote_samples_apply_by_kind() {
        use crate::obs::span::{METRIC_COUNTER_ADD, METRIC_GAUGE_SET, METRIC_HISTOGRAM_OBSERVE};
        let reg = MetricsRegistry::new();
        reg.apply_sample("w0_mailbox_hits", METRIC_COUNTER_ADD, 3.0);
        reg.apply_sample("w0_mailbox_depth", METRIC_GAUGE_SET, 2.0);
        reg.apply_sample("w0_wait_s", METRIC_HISTOGRAM_OBSERVE, 0.05);
        reg.apply_sample("ignored", 200, 1.0); // unknown kind: no-op
        assert_eq!(reg.counter("w0_mailbox_hits").get(), 3);
        assert_eq!(reg.gauge("w0_mailbox_depth").get(), 2.0);
        assert_eq!(reg.histogram("w0_wait_s", &[1.0]).count(), 1);
    }

    #[test]
    fn quantile_interpolates_and_hits_exact_bucket_edges() {
        let h = Histogram::new(&[1.0, 2.0, 4.0]);
        for v in [0.5, 1.5, 3.0, 100.0] {
            h.observe(v);
        }
        // rank lands exactly on a bucket's upper edge → the edge itself
        assert_eq!(h.quantile(0.25), Some(1.0));
        assert_eq!(h.quantile(0.5), Some(2.0));
        assert_eq!(h.quantile(0.75), Some(4.0));
        // overflow bucket: report the last finite bound
        assert_eq!(h.quantile(1.0), Some(4.0));
        // q=0 → lower edge of the first populated bucket (0.0 floor)
        assert_eq!(h.quantile(0.0), Some(0.0));
        // mid-bucket rank interpolates linearly: rank 1.5 is halfway
        // through bucket (1, 2]
        assert_eq!(h.quantile(0.375), Some(1.5));
    }

    #[test]
    fn quantile_empty_histogram_and_degenerate_inputs() {
        let h = Histogram::new(&[1.0, 2.0]);
        assert_eq!(h.quantile(0.5), None, "empty histogram has no quantile");
        assert_eq!(quantile_from_buckets(&[], &[3], 0.5), None, "no bounds, no estimate");
        // out-of-range q clamps rather than erroring
        let h2 = Histogram::new(&[2.0]);
        h2.observe(1.0);
        assert_eq!(h2.quantile(7.0), Some(2.0));
        assert_eq!(h2.quantile(-1.0), Some(0.0));
        // short count slices are zero-padded
        assert_eq!(quantile_from_buckets(&[1.0, 2.0], &[2], 0.5), Some(0.5));
    }

    #[test]
    fn bucket_counts_into_copies_without_resizing() {
        let h = Histogram::new(&[1.0, 2.0]);
        h.observe(0.5);
        h.observe(9.0);
        let mut out = [7u64; 5];
        h.bucket_counts_into(&mut out);
        assert_eq!(out, [1, 0, 1, 0, 0], "extra slots zeroed");
        let mut short = [0u64; 1];
        h.bucket_counts_into(&mut short);
        assert_eq!(short, [1]);
    }

    #[test]
    fn registry_enumeration_is_name_sorted() {
        let reg = MetricsRegistry::new();
        reg.counter("b").inc();
        reg.counter("a").inc();
        reg.gauge("g").set(1.0);
        reg.histogram("h", &[1.0]).observe(0.5);
        let names: Vec<String> = reg.counters().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["a".to_string(), "b".to_string()]);
        assert_eq!(reg.instrument_counts(), (2, 1, 1));
    }

    #[test]
    fn json_snapshot_is_deterministic() {
        let reg = MetricsRegistry::new();
        reg.counter("b").add(2);
        reg.counter("a").add(1);
        reg.histogram("h", &[1.0]).observe(0.5);
        let j = reg.to_json();
        let text = j.to_string_compact();
        // BTreeMap ordering: "a" serializes before "b"
        assert!(text.find("\"a\"").unwrap() < text.find("\"b\"").unwrap());
        assert_eq!(j.get("counters").unwrap().get("a").unwrap().as_usize().unwrap(), 1);
        let h = j.get("histograms").unwrap().get("h").unwrap();
        assert_eq!(h.get("count").unwrap().as_usize().unwrap(), 1);
    }
}
