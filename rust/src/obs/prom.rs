//! Zero-dependency Prometheus text-exposition encoder.
//!
//! [`encode`] renders a [`MetricsRegistry`] snapshot in the Prometheus
//! text format (version 0.0.4): a `# TYPE` comment per metric family,
//! counters and gauges as bare samples, histograms as CUMULATIVE
//! `_bucket{le="..."}` series closed by `le="+Inf"` plus `_sum` and
//! `_count`. Both the `sgs serve` HTTP front and the training status
//! server mount this one encoder on `/metrics`, so the two planes emit
//! byte-identical expositions for the same registry state (asserted by a
//! unit test below and re-checked end-to-end by the `monitor-smoke` CI
//! job's parser).
//!
//! Output is deterministic: instruments come out name-sorted (registry
//! BTreeMap order) within each family group (counters, gauges,
//! histograms), and floats use Rust's shortest round-trip `Display`.

use std::fmt::Write as _;

use super::metrics::{Histogram, MetricsRegistry};

/// Render every instrument in `reg` as Prometheus exposition text.
pub fn encode(reg: &MetricsRegistry) -> String {
    let mut out = String::with_capacity(4096);
    for (name, c) in reg.counters() {
        let name = sanitize(&name);
        let _ = writeln!(out, "# TYPE {name} counter");
        let _ = writeln!(out, "{name} {}", c.get());
    }
    for (name, g) in reg.gauges() {
        let name = sanitize(&name);
        let _ = writeln!(out, "# TYPE {name} gauge");
        let _ = writeln!(out, "{name} {}", fmt_value(g.get()));
    }
    for (name, h) in reg.histograms() {
        encode_histogram(&mut out, &name, &h);
    }
    out
}

fn encode_histogram(out: &mut String, name: &str, h: &Histogram) {
    let name = sanitize(name);
    let _ = writeln!(out, "# TYPE {name} histogram");
    let counts = h.bucket_counts();
    let mut cumulative = 0u64;
    for (bound, in_bucket) in h.bounds().iter().zip(&counts) {
        cumulative += in_bucket;
        let _ = writeln!(out, "{name}_bucket{{le=\"{}\"}} {cumulative}", fmt_value(*bound));
    }
    cumulative += counts.last().copied().unwrap_or(0);
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cumulative}");
    let _ = writeln!(out, "{name}_sum {}", fmt_value(h.sum()));
    let _ = writeln!(out, "{name}_count {}", h.count());
}

/// Coerce a name into the Prometheus charset `[a-zA-Z_:][a-zA-Z0-9_:]*`:
/// out-of-charset bytes become `_`, a leading digit gains a `_` prefix.
/// Registry names are already clean ASCII identifiers; this is the
/// defensive floor for remote-shipped names.
fn sanitize(name: &str) -> String {
    let mut s: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == ':' { c } else { '_' })
        .collect();
    if s.chars().next().map(|c| c.is_ascii_digit()).unwrap_or(true) {
        s.insert(0, '_');
    }
    s
}

/// Prometheus float rendering: shortest round-trip decimal, with the
/// spec's spellings for the non-finite values.
fn fmt_value(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encodes_all_three_families_with_cumulative_buckets() {
        let reg = MetricsRegistry::new();
        reg.counter("iters_total").add(7);
        reg.gauge("train_loss_last").set(0.5);
        let h = reg.histogram("staleness_mod0", &[1.0, 2.0, 4.0]);
        for v in [0.5, 1.5, 3.0, 100.0] {
            h.observe(v);
        }
        let text = encode(&reg);
        let expected = "\
# TYPE iters_total counter
iters_total 7
# TYPE train_loss_last gauge
train_loss_last 0.5
# TYPE staleness_mod0 histogram
staleness_mod0_bucket{le=\"1\"} 1
staleness_mod0_bucket{le=\"2\"} 2
staleness_mod0_bucket{le=\"4\"} 3
staleness_mod0_bucket{le=\"+Inf\"} 4
staleness_mod0_sum 105
staleness_mod0_count 4
";
        assert_eq!(text, expected);
    }

    #[test]
    fn sanitizes_hostile_names_and_nonfinite_values() {
        let reg = MetricsRegistry::new();
        reg.counter("9bad name!").inc();
        reg.gauge("g_nan").set(f64::NAN);
        reg.gauge("g_inf").set(f64::INFINITY);
        let text = encode(&reg);
        assert!(text.contains("_9bad_name_ 1"), "{text}");
        assert!(text.contains("g_nan NaN"), "{text}");
        assert!(text.contains("g_inf +Inf"), "{text}");
    }

    #[test]
    fn empty_registry_encodes_to_empty_text() {
        assert_eq!(encode(&MetricsRegistry::new()), "");
    }

    #[test]
    fn output_is_deterministic_across_registration_order() {
        let a = MetricsRegistry::new();
        a.counter("x").inc();
        a.counter("a").inc();
        let b = MetricsRegistry::new();
        b.counter("a").inc();
        b.counter("x").inc();
        assert_eq!(encode(&a), encode(&b));
    }
}
