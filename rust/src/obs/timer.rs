//! Wall-clock timing helpers used by the bench harness and cost-model
//! calibration (`simclock::cost_model`). Lives under `obs/` because this
//! is real time, not sim time — lint rule `det-wall-clock` confines
//! `Instant` to this module family (`crate::util::timer` re-exports these
//! names for existing callers).

use std::time::{Duration, Instant};

/// Accumulating stopwatch: start/stop many times, read the total.
///
/// Release-safe by construction: `start` while already running is a
/// no-op (the original start instant stands), `stop` while stopped is a
/// no-op, and the lap counter saturates instead of wrapping — misuse
/// degrades the statistics, never the process.
#[derive(Debug, Default)]
pub struct Stopwatch {
    total: Duration,
    started: Option<Instant>,
    laps: u64,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Begin a lap. Calling `start` on a running stopwatch keeps the
    /// earlier start instant (restart-while-running is a no-op), so the
    /// in-flight lap is never silently shortened.
    pub fn start(&mut self) {
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
    }

    /// Is a lap currently in flight?
    pub fn running(&self) -> bool {
        self.started.is_some()
    }

    pub fn stop(&mut self) {
        if let Some(s) = self.started.take() {
            self.total += s.elapsed();
            self.laps = self.laps.saturating_add(1);
        }
    }

    pub fn total(&self) -> Duration {
        self.total
    }

    pub fn laps(&self) -> u64 {
        self.laps
    }

    /// Mean lap time in seconds (0.0 before any lap completes).
    pub fn mean_secs(&self) -> f64 {
        if self.laps == 0 {
            0.0
        } else {
            self.total.as_secs_f64() / self.laps as f64
        }
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Run `f` `n` times after `warmup` unrecorded calls; return per-call
/// seconds for each recorded run.
pub fn sample_timings<T>(warmup: usize, n: usize, mut f: impl FnMut() -> T) -> Vec<f64> {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    (0..n)
        .map(|_| {
            let t0 = Instant::now();
            std::hint::black_box(f());
            t0.elapsed().as_secs_f64()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.start();
        std::thread::sleep(Duration::from_millis(2));
        sw.stop();
        sw.start();
        std::thread::sleep(Duration::from_millis(2));
        sw.stop();
        assert!(sw.total() >= Duration::from_millis(4));
        assert_eq!(sw.laps(), 2);
        assert!(sw.mean_secs() >= 0.002);
    }

    #[test]
    fn restart_while_running_is_a_noop() {
        // double-start keeps the FIRST start instant: the lap measures the
        // full interval and still counts exactly once
        let mut sw = Stopwatch::new();
        sw.start();
        assert!(sw.running());
        std::thread::sleep(Duration::from_millis(3));
        sw.start(); // would previously debug_assert / silently rewind
        sw.stop();
        assert!(!sw.running());
        assert_eq!(sw.laps(), 1);
        assert!(sw.total() >= Duration::from_millis(3), "lap was shortened");
        // stop on a stopped watch stays a no-op
        sw.stop();
        assert_eq!(sw.laps(), 1);
    }

    #[test]
    fn lap_count_saturates() {
        let mut sw = Stopwatch { total: Duration::ZERO, started: None, laps: u64::MAX };
        sw.start();
        sw.stop();
        assert_eq!(sw.laps(), u64::MAX, "lap counter must saturate, not wrap");
    }

    #[test]
    fn time_it_returns_value() {
        let (v, secs) = time_it(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }

    #[test]
    fn sample_timings_len() {
        let xs = sample_timings(2, 5, || 1 + 1);
        assert_eq!(xs.len(), 5);
    }
}
