//! The crate's only gateway to the host's wall clock.
//!
//! Lint rule `det-wall-clock` forbids `Instant`/`SystemTime` everywhere
//! outside `obs/` (see `xtask/src/lint.rs`), so every engine, transport,
//! and bench reads real time through the handles here. That keeps the
//! deterministic families honest — they can *hold* a [`WallClock`] for
//! observability without being able to branch on it by accident — and
//! gives the tracer one clock origin per process to timestamp spans
//! against.

use std::time::{Duration, Instant};

/// A monotonic clock anchored at its construction instant.
///
/// All span timestamps in a process are microseconds since one
/// `WallClock` origin, which is what makes per-track timestamps
/// comparable within a trace. Cloning shares the origin.
#[derive(Debug, Clone, Copy)]
pub struct WallClock {
    origin: Instant,
}

impl WallClock {
    pub fn new() -> WallClock {
        WallClock { origin: Instant::now() }
    }

    /// Microseconds elapsed since the clock's origin.
    pub fn now_us(&self) -> u64 {
        self.origin.elapsed().as_micros() as u64
    }

    /// Seconds elapsed since the clock's origin.
    pub fn elapsed_s(&self) -> f64 {
        self.origin.elapsed().as_secs_f64()
    }

    /// Re-anchor the origin to the current instant (dist workers reset at
    /// the first `Step` frame so their track roughly aligns with the
    /// coordinator's).
    pub fn reset(&mut self) {
        self.origin = Instant::now();
    }
}

impl Default for WallClock {
    fn default() -> Self {
        WallClock::new()
    }
}

/// An opaque point in the future, handed to blocking receives so the
/// transport layer can poll against real time without naming `Instant`
/// itself.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Deadline {
    at: Instant,
}

impl Deadline {
    /// A deadline `timeout` from now.
    pub fn after(timeout: Duration) -> Deadline {
        Deadline { at: Instant::now() + timeout }
    }

    /// Has the deadline passed?
    pub fn expired(&self) -> bool {
        Instant::now() >= self.at
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_is_monotonic() {
        let clk = WallClock::new();
        let a = clk.now_us();
        let b = clk.now_us();
        assert!(b >= a);
        assert!(clk.elapsed_s() >= 0.0);
    }

    #[test]
    fn reset_rewinds_the_origin() {
        let mut clk = WallClock::new();
        std::thread::sleep(Duration::from_millis(2));
        assert!(clk.now_us() >= 2_000);
        clk.reset();
        assert!(clk.now_us() < 2_000);
    }

    #[test]
    fn deadline_expires() {
        let d = Deadline::after(Duration::from_millis(0));
        std::thread::sleep(Duration::from_millis(1));
        assert!(d.expired());
        let far = Deadline::after(Duration::from_secs(3600));
        assert!(!far.expired());
    }
}
