//! Chrome trace-event export: turn a [`Tracer`] snapshot into the JSON
//! that `chrome://tracing` and [Perfetto](https://ui.perfetto.dev) load
//! directly.
//!
//! Layout: one trace *process* (`pid`) per OS process — 0 is the
//! recording process (coordinator or in-process engine), `n ≥ 1` is dist
//! worker `n − 1` — and one *thread* (`tid`) per track (agent `s·K + k`,
//! or 0 for the engine/coordinator track). Spans become `"ph": "X"`
//! complete events; `"ph": "M"` metadata names every process and thread.
//! Events are sorted by `(pid, tid, ts)` so per-track timestamps are
//! monotonic in file order — the property `sgs trace-report` and the CI
//! `trace-smoke` job validate.
//!
//! Two extra top-level keys ride along (Perfetto ignores unknown keys):
//! `sgsMeta` (run shape, clock kind, measured wall time) and
//! `sgsMetrics` (a [`MetricsRegistry`] snapshot).

use std::path::Path;

use crate::error::Result;
use crate::obs::metrics::MetricsRegistry;
use crate::obs::span::{Span, Tracer, NO_COORD};
use crate::util::json::Json;

/// Run-level context embedded as the `sgsMeta` top-level key.
#[derive(Debug, Clone)]
pub struct TraceMeta {
    /// engine name ("sim" | "threaded" | "dist")
    pub engine: String,
    pub s: usize,
    pub k: usize,
    /// iterations the run executed
    pub iters: usize,
    /// pipeline-fill iterations (first iteration with real gradients
    /// everywhere) — `sgs trace-report` splits fill vs steady state here
    pub warmup_iters: usize,
    /// modelled seconds per iteration (0 without a cost model)
    pub iter_time_s: f64,
    /// measured wall-clock seconds for the run loop
    pub wall_time_s: f64,
    /// dist worker count (0 for in-process engines)
    pub workers: usize,
    /// "wall" when span timestamps are real microseconds, "sim" when the
    /// sim engine synthesized them from the sim clock
    pub clock: &'static str,
}

impl TraceMeta {
    fn to_json(&self, dropped: u64) -> Json {
        let mut m = Json::obj();
        m.set("engine", self.engine.as_str())
            .set("s", self.s)
            .set("k", self.k)
            .set("iters", self.iters)
            .set("warmup_iters", self.warmup_iters)
            .set("iter_time_s", self.iter_time_s)
            .set("wall_time_s", self.wall_time_s)
            .set("workers", self.workers)
            .set("clock", self.clock)
            .set("dropped_spans", dropped);
        m
    }
}

fn process_name(pid: u16, meta: &TraceMeta) -> String {
    if pid == 0 {
        if meta.engine == "dist" {
            "coordinator".to_string()
        } else {
            format!("{} engine", meta.engine)
        }
    } else {
        format!("worker {}", pid - 1)
    }
}

fn track_name(span: &Span) -> String {
    if span.s == NO_COORD || span.k == NO_COORD {
        "engine".to_string()
    } else {
        format!("agent s{} k{}", span.s, span.k)
    }
}

fn meta_event(pid: u16, tid: Option<u16>, kind: &str, name: &str) -> Json {
    let mut e = Json::obj();
    e.set("ph", "M").set("pid", pid as usize).set("name", kind);
    if let Some(tid) = tid {
        e.set("tid", tid as usize);
    }
    let mut args = Json::obj();
    args.set("name", name);
    e.set("args", args);
    e
}

fn span_event(pid: u16, span: &Span) -> Json {
    let mut e = Json::obj();
    e.set("ph", "X")
        .set("pid", pid as usize)
        .set("tid", span.track as usize)
        .set("ts", span.start_us)
        .set("dur", span.dur_us)
        .set("name", span.phase.name())
        .set("cat", span.phase.name());
    let mut args = Json::obj();
    args.set("t", span.t);
    if span.s != NO_COORD {
        args.set("s", span.s as usize);
    }
    if span.k != NO_COORD {
        args.set("k", span.k as usize);
    }
    e.set("args", args);
    e
}

/// Assemble the full Chrome trace document from a tracer snapshot.
pub fn chrome_trace_json(
    tracer: &Tracer,
    metrics: Option<&MetricsRegistry>,
    meta: &TraceMeta,
) -> Json {
    let mut spans = tracer.snapshot();
    // (pid, tid, ts) order: monotonic per-track timestamps in file order,
    // with enclosing spans before the spans they contain
    spans.sort_by_key(|(pid, s)| (*pid, s.track, s.start_us, std::cmp::Reverse(s.dur_us)));

    let mut events: Vec<Json> = Vec::with_capacity(spans.len() + 8);
    let mut named_pid: Vec<u16> = Vec::new();
    let mut named_track: Vec<(u16, u16)> = Vec::new();
    for (pid, span) in &spans {
        if !named_pid.contains(pid) {
            named_pid.push(*pid);
            events.push(meta_event(*pid, None, "process_name", &process_name(*pid, meta)));
        }
        if !named_track.contains(&(*pid, span.track)) {
            named_track.push((*pid, span.track));
            events.push(meta_event(*pid, Some(span.track), "thread_name", &track_name(span)));
        }
        events.push(span_event(*pid, span));
    }

    let mut doc = Json::obj();
    doc.set("traceEvents", Json::Arr(events))
        .set("displayTimeUnit", "ms")
        .set("sgsMeta", meta.to_json(tracer.dropped()));
    if let Some(reg) = metrics {
        doc.set("sgsMetrics", reg.to_json());
    }
    doc
}

/// Write the trace document to `path` (compact JSON, parent dirs created).
pub fn write_chrome_trace(
    path: impl AsRef<Path>,
    tracer: &Tracer,
    metrics: Option<&MetricsRegistry>,
    meta: &TraceMeta,
) -> Result<()> {
    let doc = chrome_trace_json(tracer, metrics, meta);
    if let Some(parent) = path.as_ref().parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, doc.to_string_compact())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::Phase;

    fn meta() -> TraceMeta {
        TraceMeta {
            engine: "threaded".into(),
            s: 2,
            k: 2,
            iters: 4,
            warmup_iters: 2,
            iter_time_s: 0.0,
            wall_time_s: 0.5,
            workers: 0,
            clock: "wall",
        }
    }

    fn span(track: u16, phase: Phase, s: u16, k: u16, start_us: u64, dur_us: u64) -> Span {
        Span { track, phase, s, k, t: 1, start_us, dur_us }
    }

    #[test]
    fn trace_has_metadata_and_sorted_spans() {
        let tr = Tracer::new(16);
        tr.record(span(1, Phase::Bwd, 0, 1, 50, 10));
        tr.record(span(0, Phase::Fwd, 0, 0, 10, 20));
        tr.record(span(0, Phase::Gossip, 0, 0, 40, 5));
        tr.record_remote(1, &[span(0, Phase::Fwd, 1, 0, 12, 9)]);
        let doc = chrome_trace_json(&tr, None, &meta());
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 process_name + 3 thread_name + 4 spans
        let xs: Vec<&Json> =
            events.iter().filter(|e| e.get("ph").unwrap().as_str().unwrap() == "X").collect();
        assert_eq!(xs.len(), 4);
        let ms = events.len() - xs.len();
        assert_eq!(ms, 5, "process+thread metadata events");
        // per-(pid,tid) ts monotonic in file order
        let mut last: std::collections::BTreeMap<(usize, usize), f64> = Default::default();
        for e in &xs {
            let key = (
                e.get("pid").unwrap().as_usize().unwrap(),
                e.get("tid").unwrap().as_usize().unwrap(),
            );
            let ts = e.get("ts").unwrap().as_f64().unwrap();
            if let Some(prev) = last.get(&key) {
                assert!(ts >= *prev, "track {key:?} went backwards");
            }
            last.insert(key, ts);
        }
        let m = doc.get("sgsMeta").unwrap();
        assert_eq!(m.get("engine").unwrap().as_str().unwrap(), "threaded");
        assert_eq!(m.get("warmup_iters").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn enclosing_span_sorts_before_its_children() {
        let tr = Tracer::new(8);
        tr.record(span(0, Phase::GossipMix, NO_COORD, NO_COORD, 100, 10));
        tr.record(span(0, Phase::Step, NO_COORD, NO_COORD, 100, 200));
        let doc = chrome_trace_json(&tr, None, &meta());
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let xs: Vec<&str> = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "X")
            .map(|e| e.get("name").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(xs, vec!["step", "gossip_mix"], "outer span first at equal ts");
    }

    #[test]
    fn metrics_snapshot_rides_along() {
        let tr = Tracer::new(4);
        tr.record(span(0, Phase::Fwd, 0, 0, 0, 1));
        let reg = MetricsRegistry::new();
        reg.counter("iters_total").add(4);
        let doc = chrome_trace_json(&tr, Some(&reg), &meta());
        let m = doc.get("sgsMetrics").unwrap();
        assert_eq!(
            m.get("counters").unwrap().get("iters_total").unwrap().as_usize().unwrap(),
            4
        );
    }

    #[test]
    fn write_round_trips_through_the_parser() {
        let tr = Tracer::new(4);
        tr.record(span(0, Phase::Fwd, 0, 0, 0, 7));
        let dir = std::env::temp_dir().join("sgs_trace_export");
        let path = dir.join("trace.json");
        write_chrome_trace(&path, &tr, None, &meta()).unwrap();
        let j = Json::from_file(&path).unwrap();
        assert_eq!(j.get("traceEvents").unwrap().as_arr().unwrap().len(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }
}
