//! Step-size strategies (Section 5 eqs. (20)–(21), Assumption 4.6).

use crate::error::{Error, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum LrSchedule {
    /// Strategy I: η_t = η ∀t (eq. (20)).
    Const(f64),
    /// Strategy II: piecewise-constant drops; (boundary, value-after) pairs
    /// applied in order. `base` is η before the first boundary (eq. (21)).
    Piecewise { base: f64, drops: Vec<(usize, f64)> },
    /// Diminishing η_t = η*/(t+1) — satisfies Assumption 4.6 when η* ≤ S/ϱ.
    Diminishing { eta0: f64 },
}

impl LrSchedule {
    pub fn at(&self, t: usize) -> f64 {
        match self {
            LrSchedule::Const(eta) => *eta,
            LrSchedule::Piecewise { base, drops } => {
                let mut eta = *base;
                for &(boundary, value) in drops {
                    if t > boundary {
                        eta = value;
                    }
                }
                eta
            }
            LrSchedule::Diminishing { eta0 } => eta0 / (t as f64 + 1.0),
        }
    }

    /// The paper's Strategy I (η = 0.1).
    pub fn strategy_1() -> LrSchedule {
        LrSchedule::Const(0.1)
    }

    /// The paper's Strategy II (eq. (21)), with breakpoints scaled from the
    /// 50 000-iteration run to `total_iters` proportionally
    /// (15k/30k/40k out of 50k → 0.3/0.6/0.8).
    pub fn strategy_2(total_iters: usize) -> LrSchedule {
        LrSchedule::Piecewise {
            base: 0.1,
            drops: vec![
                (total_iters * 3 / 10, 0.01),
                (total_iters * 6 / 10, 0.001),
                (total_iters * 8 / 10, 0.0001),
            ],
        }
    }

    /// Parse "const:0.1" | "piecewise:0.1@0,0.01@300,..." | "dim:0.5".
    pub fn parse(s: &str) -> Result<LrSchedule> {
        let bad = || Error::Config(format!("bad lr schedule {s:?}"));
        if let Some(v) = s.strip_prefix("const:") {
            return Ok(LrSchedule::Const(v.parse().map_err(|_| bad())?));
        }
        if let Some(v) = s.strip_prefix("dim:") {
            return Ok(LrSchedule::Diminishing {
                eta0: v.parse().map_err(|_| bad())?,
            });
        }
        if let Some(spec) = s.strip_prefix("piecewise:") {
            let mut base = None;
            let mut drops = Vec::new();
            for part in spec.split(',') {
                let (val, at) = part.split_once('@').ok_or_else(bad)?;
                let val: f64 = val.parse().map_err(|_| bad())?;
                let at: usize = at.parse().map_err(|_| bad())?;
                if at == 0 && base.is_none() {
                    base = Some(val);
                } else {
                    drops.push((at, val));
                }
            }
            return Ok(LrSchedule::Piecewise {
                base: base.ok_or_else(bad)?,
                drops,
            });
        }
        Err(bad())
    }

    pub fn describe(&self) -> String {
        match self {
            LrSchedule::Const(eta) => format!("const:{eta}"),
            LrSchedule::Piecewise { base, drops } => {
                let mut s = format!("piecewise:{base}@0");
                for (at, v) in drops {
                    s.push_str(&format!(",{v}@{at}"));
                }
                s
            }
            LrSchedule::Diminishing { eta0 } => format!("dim:{eta0}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_1_constant() {
        let lr = LrSchedule::strategy_1();
        assert_eq!(lr.at(0), 0.1);
        assert_eq!(lr.at(49_999), 0.1);
    }

    #[test]
    fn strategy_2_matches_eq21_at_full_scale() {
        // at 50k iters the breakpoints are exactly the paper's 15k/30k/40k
        let lr = LrSchedule::strategy_2(50_000);
        assert_eq!(lr.at(0), 0.1);
        assert_eq!(lr.at(15_000), 0.1); // t ≤ 15000
        assert_eq!(lr.at(15_001), 0.01);
        assert_eq!(lr.at(30_000), 0.01);
        assert_eq!(lr.at(30_001), 0.001);
        assert_eq!(lr.at(40_000), 0.001);
        assert_eq!(lr.at(40_001), 0.0001);
    }

    #[test]
    fn diminishing_satisfies_assumption_4_6() {
        let lr = LrSchedule::Diminishing { eta0: 0.5 };
        // decreasing
        for t in 0..100 {
            assert!(lr.at(t) > lr.at(t + 1));
        }
        // Σ η_t diverges (harmonic) but Σ η_t² converges: check partial sums
        let sum1: f64 = (0..10_000).map(|t| lr.at(t)).sum();
        let sum2: f64 = (0..10_000).map(|t| lr.at(t).powi(2)).sum();
        assert!(sum1 > 4.0);
        assert!(sum2 < 0.5); // 0.25 · π²/6 ≈ 0.411
    }

    #[test]
    fn parse_roundtrip() {
        for s in ["const:0.1", "dim:0.5", "piecewise:0.1@0,0.01@300,0.001@600"] {
            let lr = LrSchedule::parse(s).unwrap();
            assert_eq!(LrSchedule::parse(&lr.describe()).unwrap(), lr);
        }
        assert!(LrSchedule::parse("cosine:1").is_err());
        assert!(LrSchedule::parse("piecewise:nope").is_err());
    }
}
