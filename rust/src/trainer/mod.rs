//! The distributed trainer: S pipeline groups + per-module-group gossip.
//!
//! This single engine realizes all four Section-5 methods as (S, K) points:
//! centralized (1,1), decoupled model (1,2), data-parallel (4,1), and the
//! paper's distributed method (4,2) — plus any other grid point.

pub mod lr;
pub mod opt;
pub mod sgd;

pub use lr::LrSchedule;
pub use opt::OptimizerKind;

use std::sync::Arc;

use crate::config::ExperimentConfig;
use crate::consensus::{consensus_error, GossipMixer};
use crate::data::{shard_even, Dataset, MiniBatchSampler};
use crate::error::{Error, Result};
use crate::graph::{max_safe_alpha, xiao_boyd_weights, Graph};
use crate::linalg::Mat;
use crate::metrics::{Record, Recorder};
use crate::nn::init::init_params;
use crate::nn::LayerShape;
use crate::pipeline::module_agent::ModuleAgent;
use crate::pipeline::sim::{GroupStepOut, PipelineGroup};
use crate::runtime::ComputeBackend;
use crate::staleness::partition_layers;
use crate::tensor::Tensor;
use crate::checkpoint::{Checkpoint, ResumeState};
use crate::util::rng::Pcg32;

/// A ready-to-run experiment (sim engine).
///
/// Construction is crate-private: external code drives training through
/// [`crate::session::Session`], the one public entry point for both engines.
pub struct Trainer {
    pub cfg: ExperimentConfig,
    backend: Arc<dyn ComputeBackend>,
    ds: Arc<Dataset>,
    groups: Vec<PipelineGroup>,
    mixer: Option<GossipMixer>,
    pub p_matrix: Option<Mat>,
    layers: Vec<LayerShape>,
    probe: (Tensor, Tensor),
    /// modelled seconds per iteration (from simclock; 0 if not set)
    pub iter_time_s: f64,
    t: i64,
    /// iterations completed before a checkpoint restore (LR/record offset)
    t_offset: usize,
    recorder: Recorder,
    /// per-module compensation correction norms of the last step, group-mean
    last_correction: Vec<f64>,
    /// workers for stepping independent groups concurrently
    /// (`ExperimentConfig::compute_threads`; groups are data-independent
    /// within an iteration, so any worker count is bit-identical)
    group_threads: usize,
    /// per-group outputs of the last step (reused buffer)
    step_outs: Vec<GroupStepOut>,
    /// per-step loss scratch (reused buffer)
    loss_buf: Vec<f64>,
    /// gossip gather scratch: replicas move out, mix, move back (reused)
    gossip_buf: Vec<Tensor>,
}

impl Trainer {
    /// Build groups, shards, samplers, and the gossip mixer.
    ///
    /// All S groups start from IDENTICAL weights (the common choice; the
    /// consensus analysis then has δ(0) = 0).
    pub(crate) fn new(
        cfg: ExperimentConfig,
        backend: Arc<dyn ComputeBackend>,
        ds: Arc<Dataset>,
    ) -> Result<Trainer> {
        cfg.validate()?;
        let layers = cfg.model.layers();
        if backend.layers() != &layers[..] {
            return Err(crate::error::Error::Config(format!(
                "backend layer stack {:?} differs from config model {:?}",
                backend.layers(),
                layers
            )));
        }

        let mut root_rng = Pcg32::new(cfg.seed);
        let init = init_params(&mut root_rng.fork(0x1217), &layers);
        let k_modules = cfg.k;
        let bounds = partition_layers(layers.len(), k_modules);

        let shards = shard_even(&ds, cfg.s, cfg.seed ^ 0xDA7A)?;
        let mut groups = Vec::with_capacity(cfg.s);
        for (s, shard) in shards.into_iter().enumerate() {
            let modules: Vec<ModuleAgent> = bounds
                .iter()
                .enumerate()
                .map(|(k, &(lo, hi))| {
                    ModuleAgent::with_strategies(
                        k,
                        lo,
                        hi,
                        init[lo..hi].to_vec(),
                        cfg.optimizer,
                        cfg.compensate,
                    )
                })
                .collect();
            let sampler =
                MiniBatchSampler::new(shard, cfg.batch, cfg.seed ^ (0xBA7C << 8) ^ s as u64);
            groups.push(PipelineGroup::with_mode(s, modules, sampler, cfg.mode));
        }

        // gossip machinery only when there is someone to gossip with
        let (mixer, p_matrix) = if cfg.s > 1 {
            let g = Graph::build(cfg.topology, cfg.s)?;
            let alpha = cfg.alpha.unwrap_or_else(|| max_safe_alpha(&g));
            let p = xiao_boyd_weights(&g, alpha)?;
            (Some(GossipMixer::new(&p, 0)), Some(p))
        } else {
            (None, None)
        };

        // fixed probe batch for eval (drawn from the full dataset)
        let mut probe_rng = root_rng.fork(0x9E0B);
        let probe_idx = probe_rng.sample_indices(ds.len(), cfg.batch.min(ds.len()));
        let probe = ds.gather(&probe_idx);

        let group_threads = crate::nn::resolve_threads(cfg.compute_threads).min(cfg.s);
        let iters = cfg.iters;
        let s_groups = cfg.s;
        Ok(Trainer {
            cfg,
            backend,
            ds,
            groups,
            mixer,
            p_matrix,
            layers,
            probe,
            iter_time_s: 0.0,
            t: 0,
            t_offset: 0,
            // capacity for the whole run keeps the steady-state push
            // allocation-free (tests/alloc_guard.rs)
            recorder: Recorder::with_capacity(iters),
            last_correction: vec![0.0; k_modules],
            group_threads,
            step_outs: vec![GroupStepOut::default(); s_groups],
            loss_buf: Vec::with_capacity(s_groups),
            gossip_buf: Vec::with_capacity(s_groups),
        })
    }

    /// Step every group once — concurrently over `group_threads` workers
    /// when there is more than one group. Groups only share the (Sync)
    /// backend and dataset within an iteration, so the fan-out computes
    /// exactly the serial loop's bits; results land in `step_outs` in
    /// group order either way.
    fn step_groups(&mut self, t: i64, eta: f64) -> Result<()> {
        let backend: &dyn ComputeBackend = self.backend.as_ref();
        let ds: &Dataset = &self.ds;
        let nt = self.group_threads.min(self.groups.len());
        if nt <= 1 {
            for (g, out) in self.groups.iter_mut().zip(self.step_outs.iter_mut()) {
                *out = g.step(backend, ds, t, eta)?;
            }
            return Ok(());
        }
        let chunk = self.groups.len().div_ceil(nt);
        let groups = &mut self.groups;
        let outs = &mut self.step_outs;
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::with_capacity(nt);
            for (gc, oc) in groups.chunks_mut(chunk).zip(outs.chunks_mut(chunk)) {
                handles.push(scope.spawn(move || -> Result<()> {
                    for (g, o) in gc.iter_mut().zip(oc.iter_mut()) {
                        *o = g.step(backend, ds, t, eta)?;
                    }
                    Ok(())
                }));
            }
            for h in handles {
                match h.join() {
                    Ok(res) => res?,
                    Err(_) => {
                        return Err(Error::Schedule("group thread panicked".into()));
                    }
                }
            }
            Ok(())
        })
    }

    pub fn groups(&self) -> &[PipelineGroup] {
        &self.groups
    }

    /// Snapshot the current weights + absolute iteration count, with the
    /// exact-resume payload attached (sampler positions, velocity, in-flight
    /// pipeline state). `save` persists only the weights-only core.
    pub fn checkpoint(&self) -> Checkpoint {
        Checkpoint::new(
            self.t_offset + self.t as usize,
            self.groups.iter().map(|g| g.all_params()).collect(),
            self.layers.clone(),
        )
        .with_resume(self.resume_state())
    }

    fn resume_state(&self) -> ResumeState {
        ResumeState {
            t: self.t,
            t_offset: self.t_offset,
            groups: self.groups.iter().map(|g| g.resume_state()).collect(),
        }
    }

    /// Restore from a checkpoint and continue training from its iteration
    /// (LR schedule resumes at the right position).
    ///
    /// With an exact-resume payload (`ck.resume`, present on in-memory
    /// engine checkpoints) the continuation is bit-identical to the
    /// uninterrupted run. Weights-only checkpoints (disk round-trips) fall
    /// back to refill semantics: transient state is dropped, samplers
    /// restart, and the first `warmup_iters()` post-restore updates use
    /// zero gradients, exactly like a fresh start (eq. (10)'s τ < 0
    /// convention).
    pub fn restore(&mut self, ck: &Checkpoint) -> Result<()> {
        if ck.groups.len() != self.groups.len() {
            return Err(crate::error::Error::Config(format!(
                "checkpoint has {} groups, trainer has {}",
                ck.groups.len(),
                self.groups.len()
            )));
        }
        if ck.layers != self.layers {
            return Err(crate::error::Error::Config(
                "checkpoint layer stack differs from trainer model".into(),
            ));
        }
        for (group, saved) in self.groups.iter_mut().zip(&ck.groups) {
            let mut off = 0;
            for module in group.modules.iter_mut() {
                for p in module.params.iter_mut() {
                    *p = saved[off].clone();
                    off += 1;
                }
            }
        }
        match &ck.resume {
            Some(rs) => {
                if rs.groups.len() != self.groups.len() {
                    return Err(crate::error::Error::Config(format!(
                        "resume state has {} groups, trainer has {}",
                        rs.groups.len(),
                        self.groups.len()
                    )));
                }
                self.t = rs.t;
                self.t_offset = rs.t_offset;
                for (group, gr) in self.groups.iter_mut().zip(&rs.groups) {
                    group.restore_resume(gr);
                }
            }
            None => {
                self.t = 0;
                self.t_offset = ck.iteration;
                let seed = self.cfg.seed;
                for (s, group) in self.groups.iter_mut().enumerate() {
                    group.clear_transient();
                    group.reset_sampler(seed ^ (0xBA7C << 8) ^ s as u64);
                }
            }
        }
        Ok(())
    }

    /// Group-averaged parameters W̄(t) (the quantity the theory tracks) —
    /// the shared [`crate::consensus::averaged_params`] reduction, so all
    /// engines' eval paths agree bitwise by construction.
    pub fn averaged_params(&self) -> Vec<(Tensor, Tensor)> {
        let per_group: Vec<Vec<(Tensor, Tensor)>> =
            self.groups.iter().map(|g| g.all_params()).collect();
        crate::consensus::averaged_params(&per_group)
    }

    /// δ(t) of eq. (22) over the current per-group parameters.
    pub fn consensus_delta(&self) -> f64 {
        if self.groups.len() < 2 {
            return 0.0;
        }
        let per_group: Vec<Vec<(Tensor, Tensor)>> =
            self.groups.iter().map(|g| g.all_params()).collect();
        consensus_error(&per_group)
    }

    /// One global iteration: every group steps (fwd/bwd/update, eq. 13a),
    /// then each model-group gossips (eq. 13b).
    pub fn step(&mut self) -> Result<Record> {
        let t = self.t;
        let eta = self.cfg.lr.at(self.t_offset + t as usize);

        self.step_groups(t, eta)?;
        self.loss_buf.clear();
        for out in &self.step_outs {
            if let Some(l) = out.loss {
                self.loss_buf.push(l as f64);
            }
        }
        // group-mean correction, ascending-s then /S — the same reduction
        // the threaded engine runs (group_mean_correction), in place
        let s_count = self.groups.len() as f64;
        for c in self.last_correction.iter_mut() {
            *c = 0.0;
        }
        for g in &self.groups {
            for (acc, c) in self.last_correction.iter_mut().zip(g.last_correction()) {
                *acc += c;
            }
        }
        for c in self.last_correction.iter_mut() {
            *c /= s_count;
        }

        // gossip: for every module's every parameter tensor, mix across groups
        if let Some(mixer) = &mut self.mixer {
            let k_modules = self.groups[0].k();
            for k in 0..k_modules {
                let n_local = self.groups[0].modules[k].n_layers();
                for l in 0..n_local {
                    for which in 0..2 {
                        // gather replicas (move out, mix, move back);
                        // Tensor::empty + the reused gather buffer keep
                        // this allocation-free
                        self.gossip_buf.clear();
                        for g in self.groups.iter_mut() {
                            let p = &mut g.modules[k].params[l];
                            self.gossip_buf.push(std::mem::replace(
                                if which == 0 { &mut p.0 } else { &mut p.1 },
                                Tensor::empty(),
                            ));
                        }
                        // r rounds: contraction γ^r per iteration
                        for _ in 0..self.cfg.gossip_rounds {
                            mixer.mix(&mut self.gossip_buf);
                        }
                        for (g, r) in self.groups.iter_mut().zip(self.gossip_buf.drain(..)) {
                            let p = &mut g.modules[k].params[l];
                            *(if which == 0 { &mut p.0 } else { &mut p.1 }) = r;
                        }
                    }
                }
            }
        }

        self.t += 1;
        let t_us = self.t_offset + t as usize;

        // LOCKSTEP with ThreadedEngine::step's event assembly: the eval/δ
        // cadence conditions, sim_time formula, and loss mean must stay
        // identical or the engines' asserted bit-equality breaks
        // (tests/integration_engines.rs).
        let mut record = Record {
            t: t_us,
            lr: eta,
            train_loss: (!self.loss_buf.is_empty()).then(|| crate::util::mean(&self.loss_buf)),
            sim_time_s: (self.t_offset as f64 + self.t as f64) * self.iter_time_s,
            ..Default::default()
        };

        if self.cfg.delta_every > 0 && t_us % self.cfg.delta_every == 0 {
            record.delta = Some(self.consensus_delta());
        }
        if self.cfg.eval_every > 0 && (t_us % self.cfg.eval_every == 0 || t_us + 1 == self.cfg.iters)
        {
            let avg = self.averaged_params();
            let (x, oh) = &self.probe;
            record.eval_loss = Some(self.backend.eval_loss(x, oh, &avg)? as f64);
            let logits = crate::nn::full_forward(x, &avg, &self.layers);
            record.eval_acc = Some(crate::nn::accuracy(&logits, oh));
        }

        self.recorder.push(record.clone());
        Ok(record)
    }

    /// Run up to the configured iteration budget (absolute — a restored
    /// trainer only runs the remaining iterations); returns the recorder.
    pub fn run(&mut self) -> Result<&Recorder> {
        while self.iterations_done() < self.cfg.iters {
            self.step()?;
        }
        Ok(&self.recorder)
    }

    pub fn recorder(&self) -> &Recorder {
        &self.recorder
    }

    /// Per-module compensation correction norms of the last [`Self::step`]
    /// (group mean of ‖g_eff − g_raw‖₂; zeros before the first step or
    /// under the `none` baseline).
    pub fn last_correction(&self) -> &[f64] {
        &self.last_correction
    }

    /// Absolute iterations completed (restore offset included).
    pub fn iterations_done(&self) -> usize {
        self.t_offset + self.t as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelShape;
    use crate::data::synthetic::SyntheticSpec;
    use crate::runtime::NativeBackend;

    fn tiny_cfg(s: usize, k: usize) -> ExperimentConfig {
        ExperimentConfig {
            name: "test".into(),
            s,
            k,
            model: ModelShape { d_in: 12, hidden: 10, blocks: 2, classes: 3 }.into(),
            batch: 16,
            iters: 200,
            lr: LrSchedule::Const(0.1),
            seed: 7,
            dataset_n: 400,
            delta_every: 5,
            eval_every: 20,
            ..ExperimentConfig::default()
        }
    }

    fn run_cfg(cfg: ExperimentConfig) -> (RecorderSnapshot, f64) {
        let ds = Arc::new(
            SyntheticSpec::small(cfg.dataset_n, cfg.model.d_in(), cfg.model.classes(), 3).generate(),
        );
        let backend: Arc<dyn ComputeBackend> =
            Arc::new(NativeBackend::new(cfg.model.layers(), cfg.batch));
        let mut tr = Trainer::new(cfg, backend, ds).unwrap();
        tr.run().unwrap();
        let delta = tr.consensus_delta();
        // smooth over windows: single-batch losses are noisy at batch 16
        let losses: Vec<f64> = tr
            .recorder()
            .records
            .iter()
            .filter_map(|r| r.train_loss)
            .collect();
        let head = crate::util::mean(&losses[..20.min(losses.len())]);
        let n = losses.len();
        let tail = crate::util::mean(&losses[n.saturating_sub(20)..]);
        (
            RecorderSnapshot {
                final_train_loss: Some(tail),
                first_train_loss: Some(head),
            },
            delta,
        )
    }

    struct RecorderSnapshot {
        final_train_loss: Option<f64>,
        first_train_loss: Option<f64>,
    }

    #[test]
    fn all_four_paper_methods_learn() {
        for (s, k) in [(1, 1), (1, 2), (4, 1), (4, 2)] {
            let (snap, _) = run_cfg(tiny_cfg(s, k));
            let first = snap.first_train_loss.unwrap();
            let last = snap.final_train_loss.unwrap();
            assert!(
                last < first * 0.9,
                "S={s},K={k}: loss {first} -> {last} did not drop"
            );
        }
    }

    #[test]
    fn consensus_error_stays_small() {
        // identical init ⇒ δ(0)=0; gossip keeps δ(t) below O(η) (Thm 4.5)
        let (_, delta) = run_cfg(tiny_cfg(4, 2));
        assert!(delta < 0.5, "delta blew up: {delta}");
    }

    #[test]
    fn s1_has_zero_delta() {
        let (_, delta) = run_cfg(tiny_cfg(1, 2));
        assert_eq!(delta, 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let (a, da) = run_cfg(tiny_cfg(2, 2));
        let (b, db) = run_cfg(tiny_cfg(2, 2));
        assert_eq!(a.final_train_loss, b.final_train_loss);
        assert_eq!(da, db);
    }

    #[test]
    fn dbp_mode_learns_and_differs_from_fd() {
        // the Huo-et-al backward-unlocked baseline must train, and its
        // halved staleness gives a different trajectory than FD
        let mut fd = tiny_cfg(2, 3);
        fd.iters = 100;
        let mut dbp = fd.clone();
        dbp.mode = crate::staleness::PipelineMode::BackwardUnlocked;
        let (fd_snap, _) = run_cfg(fd);
        let (dbp_snap, _) = run_cfg(dbp);
        let dbp_first = dbp_snap.first_train_loss.unwrap();
        let dbp_last = dbp_snap.final_train_loss.unwrap();
        assert!(dbp_last < dbp_first, "dbp did not learn: {dbp_first} -> {dbp_last}");
        assert_ne!(fd_snap.final_train_loss, dbp_snap.final_train_loss);
    }

    #[test]
    fn compensation_strategies_train_through_pipeline() {
        // dc and accum must not break learning on the (2,2) grid point;
        // accum halves the update count, so give it the same budget
        for comp in [
            crate::compensate::CompensatorKind::DelayComp { lambda: 0.04 },
            crate::compensate::CompensatorKind::Accumulate { n: 2 },
        ] {
            let mut cfg = tiny_cfg(2, 2);
            cfg.compensate = comp;
            let (snap, delta) = run_cfg(cfg);
            let first = snap.first_train_loss.unwrap();
            let last = snap.final_train_loss.unwrap();
            assert!(last < first * 0.9, "{comp:?}: loss {first} -> {last} did not drop");
            assert!(delta.is_finite() && delta < 1.0);
        }
    }

    #[test]
    fn dc_lambda_zero_matches_none_bitwise() {
        // the λ=0 degenerate case must be the EXACT baseline trajectory
        let mut none = tiny_cfg(2, 2);
        none.iters = 60;
        let mut dc0 = none.clone();
        dc0.compensate = crate::compensate::CompensatorKind::DelayComp { lambda: 0.0 };
        let (a, da) = run_cfg(none);
        let (b, db) = run_cfg(dc0);
        assert_eq!(a.final_train_loss, b.final_train_loss);
        assert_eq!(da, db);
    }

    #[test]
    fn accum_n1_matches_none_bitwise() {
        let mut none = tiny_cfg(2, 2);
        none.iters = 60;
        let mut acc1 = none.clone();
        acc1.compensate = crate::compensate::CompensatorKind::Accumulate { n: 1 };
        let (a, da) = run_cfg(none);
        let (b, db) = run_cfg(acc1);
        assert_eq!(a.final_train_loss, b.final_train_loss);
        assert_eq!(da, db);
    }

    #[test]
    fn momentum_optimizer_trains_through_pipeline() {
        let mut cfg = tiny_cfg(2, 2);
        cfg.iters = 150;
        cfg.lr = LrSchedule::Const(0.05);
        cfg.optimizer = crate::trainer::opt::OptimizerKind::Momentum { beta: 0.9 };
        let (snap, delta) = run_cfg(cfg);
        assert!(
            snap.final_train_loss.unwrap() < snap.first_train_loss.unwrap(),
            "momentum run did not learn"
        );
        assert!(delta.is_finite() && delta < 1.0);
    }

    #[test]
    fn more_gossip_rounds_tighten_consensus() {
        // γ^r contraction: r=3 rounds per iteration must leave a smaller
        // consensus floor than r=1 on a slow-mixing ring
        let mut one = tiny_cfg(4, 2);
        one.iters = 120;
        let mut three = one.clone();
        three.gossip_rounds = 3;
        let (_, d1) = run_cfg(one);
        let (_, d3) = run_cfg(three);
        assert!(
            d3 < d1,
            "3 rounds should beat 1: delta {d3:.3e} vs {d1:.3e}"
        );
    }

    #[test]
    fn checkpoint_restore_resumes_training() {
        let cfg = tiny_cfg(2, 2);
        let ds = Arc::new(SyntheticSpec::small(cfg.dataset_n, 12, 3, 3).generate());
        let backend: Arc<dyn ComputeBackend> =
            Arc::new(NativeBackend::new(cfg.model.layers(), cfg.batch));

        // train 50, checkpoint (to disk), restore into a FRESH trainer
        let mut a = Trainer::new(cfg.clone(), backend.clone(), ds.clone()).unwrap();
        for _ in 0..50 {
            a.step().unwrap();
        }
        let dir = std::env::temp_dir().join("sgs_trainer_ckpt");
        let base = dir.join("ck");
        a.checkpoint().save(&base).unwrap();

        let ck = Checkpoint::load(&base).unwrap();
        assert_eq!(ck.iteration, 50);
        assert!(ck.resume.is_none(), "disk checkpoints are weights-only");
        let mut b = Trainer::new(cfg, backend, ds).unwrap();
        b.restore(&ck).unwrap();

        // restored weights match exactly
        for (ga, gb) in a.groups().iter().zip(b.groups()) {
            for ((w1, b1), (w2, b2)) in ga.all_params().iter().zip(gb.all_params().iter()) {
                assert_eq!(w1, w2);
                assert_eq!(b1, b2);
            }
        }
        // resumed trainer keeps learning and reports absolute iterations
        for _ in 0..30 {
            b.step().unwrap();
        }
        let recs = &b.recorder().records;
        assert_eq!(recs[0].t, 50);
        assert_eq!(recs[29].t, 79);
        assert!(recs.iter().rev().find_map(|r| r.train_loss).unwrap() < 2.0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn restore_rejects_mismatched_shapes() {
        let cfg = tiny_cfg(2, 2);
        let ds = Arc::new(SyntheticSpec::small(cfg.dataset_n, 12, 3, 3).generate());
        let backend: Arc<dyn ComputeBackend> =
            Arc::new(NativeBackend::new(cfg.model.layers(), cfg.batch));
        let a = Trainer::new(cfg.clone(), backend.clone(), ds.clone()).unwrap();
        let mut ck = a.checkpoint();
        ck.groups.pop(); // wrong group count
        let mut b = Trainer::new(cfg, backend, ds).unwrap();
        assert!(b.restore(&ck).is_err());
    }

    #[test]
    fn exact_restore_continues_bit_identically() {
        // full-state (in-memory) checkpoints must resume the exact stream:
        // interrupted-and-restored == uninterrupted, bit for bit
        let mut cfg = tiny_cfg(2, 2);
        cfg.iters = 40;
        cfg.optimizer = crate::trainer::opt::OptimizerKind::Momentum { beta: 0.9 };
        let ds = Arc::new(SyntheticSpec::small(cfg.dataset_n, 12, 3, 3).generate());
        let backend: Arc<dyn ComputeBackend> =
            Arc::new(NativeBackend::new(cfg.model.layers(), cfg.batch));

        let mut full = Trainer::new(cfg.clone(), backend.clone(), ds.clone()).unwrap();
        full.run().unwrap();

        let mut part = Trainer::new(cfg.clone(), backend.clone(), ds.clone()).unwrap();
        for _ in 0..17 {
            part.step().unwrap();
        }
        let ck = part.checkpoint();
        assert!(ck.resume.is_some());
        let mut resumed = Trainer::new(cfg, backend, ds).unwrap();
        resumed.restore(&ck).unwrap();
        resumed.run().unwrap();

        for (a, b) in full.recorder().records[17..]
            .iter()
            .zip(&resumed.recorder().records)
        {
            assert_eq!(a.t, b.t);
            assert_eq!(a.train_loss, b.train_loss, "t={}", a.t);
        }
        for (ga, gb) in full.groups().iter().zip(resumed.groups()) {
            for ((w1, b1), (w2, b2)) in ga.all_params().iter().zip(gb.all_params().iter()) {
                assert_eq!(w1, w2);
                assert_eq!(b1, b2);
            }
        }
    }

    #[test]
    fn averaged_params_shape() {
        let cfg = tiny_cfg(3, 2);
        let ds = Arc::new(SyntheticSpec::small(cfg.dataset_n, 12, 3, 3).generate());
        let backend: Arc<dyn ComputeBackend> =
            Arc::new(NativeBackend::new(cfg.model.layers(), cfg.batch));
        let tr = Trainer::new(cfg, backend, ds).unwrap();
        let avg = tr.averaged_params();
        assert_eq!(avg.len(), 4);
        assert_eq!(avg[0].0.shape(), &[12, 10]);
    }
}
