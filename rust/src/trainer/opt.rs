//! Optimizers for the stale-gradient update (eq. (13a) generalized).
//!
//! The paper analyses plain SGD; momentum under gradient staleness is its
//! natural extension (and the classic failure mode of asynchronous
//! methods — stale momentum compounds stale gradients, which is why the
//! ablation in `benches/ablation_sk.rs`-style sweeps matters). State is
//! per-module so both pipeline engines share the same mechanics.

use crate::error::{Error, Result};
use crate::tensor::Tensor;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OptimizerKind {
    /// w ← w − η·scale·g  (the paper's update)
    Sgd,
    /// v ← β v + g; w ← w − η·scale·v  (heavy-ball)
    Momentum { beta: f64 },
    /// v ← β v + g; w ← w − η·scale·(g + β v)  (Nesterov-style lookahead)
    Nesterov { beta: f64 },
}

impl OptimizerKind {
    /// Parse "sgd" | "momentum:0.9" | "nesterov:0.9" (case-insensitive and
    /// whitespace-tolerant, like `BackendKind`/`EngineKind`). β must lie in
    /// [0, 1): anything else diverges under the v ← βv + g recursion.
    pub fn parse(s: &str) -> Result<OptimizerKind> {
        let norm = s.trim().to_ascii_lowercase();
        let bad = || Error::Config(format!("bad optimizer {s:?} (want sgd|momentum:B|nesterov:B)"));
        let beta_of = |v: &str| -> Result<f64> {
            let beta: f64 = v.parse().map_err(|_| bad())?;
            if !(0.0..1.0).contains(&beta) {
                return Err(Error::Config(format!(
                    "optimizer beta must be in [0, 1), got {beta}"
                )));
            }
            Ok(beta)
        };
        if norm == "sgd" {
            return Ok(OptimizerKind::Sgd);
        }
        if let Some(v) = norm.strip_prefix("momentum:") {
            return Ok(OptimizerKind::Momentum { beta: beta_of(v)? });
        }
        if let Some(v) = norm.strip_prefix("nesterov:") {
            return Ok(OptimizerKind::Nesterov { beta: beta_of(v)? });
        }
        Err(bad())
    }

    pub fn describe(&self) -> String {
        match self {
            OptimizerKind::Sgd => "sgd".into(),
            OptimizerKind::Momentum { beta } => format!("momentum:{beta}"),
            OptimizerKind::Nesterov { beta } => format!("nesterov:{beta}"),
        }
    }
}

/// Per-module optimizer state: one velocity buffer per parameter tensor.
#[derive(Debug, Clone)]
pub struct ModuleOptimizer {
    pub kind: OptimizerKind,
    /// (v_W, v_b) per local layer; allocated lazily on first use
    velocity: Vec<(Tensor, Tensor)>,
}

impl ModuleOptimizer {
    pub fn new(kind: OptimizerKind) -> ModuleOptimizer {
        ModuleOptimizer {
            kind,
            velocity: Vec::new(),
        }
    }

    /// Apply the stale-gradient step to `params` in place.
    /// `scale` is the |D_s|/N factor of eq. (13a).
    pub fn step(
        &mut self,
        params: &mut [(Tensor, Tensor)],
        grads: &[(Tensor, Tensor)],
        eta: f64,
        scale: f64,
    ) {
        debug_assert_eq!(params.len(), grads.len());
        let lr = (eta * scale) as f32;
        match self.kind {
            OptimizerKind::Sgd => {
                for ((w, b), (g_w, g_b)) in params.iter_mut().zip(grads) {
                    w.axpy(-lr, g_w);
                    b.axpy(-lr, g_b);
                }
            }
            OptimizerKind::Momentum { beta } => {
                self.ensure_velocity(params);
                let beta = beta as f32;
                for (((w, b), (g_w, g_b)), (v_w, v_b)) in
                    params.iter_mut().zip(grads).zip(&mut self.velocity)
                {
                    v_w.scale(beta);
                    v_w.axpy(1.0, g_w);
                    v_b.scale(beta);
                    v_b.axpy(1.0, g_b);
                    w.axpy(-lr, v_w);
                    b.axpy(-lr, v_b);
                }
            }
            OptimizerKind::Nesterov { beta } => {
                self.ensure_velocity(params);
                let beta = beta as f32;
                for (((w, b), (g_w, g_b)), (v_w, v_b)) in
                    params.iter_mut().zip(grads).zip(&mut self.velocity)
                {
                    v_w.scale(beta);
                    v_w.axpy(1.0, g_w);
                    v_b.scale(beta);
                    v_b.axpy(1.0, g_b);
                    // lookahead: g + β v
                    w.axpy(-lr, g_w);
                    w.axpy(-lr * beta, v_w);
                    b.axpy(-lr, g_b);
                    b.axpy(-lr * beta, v_b);
                }
            }
        }
    }

    /// Clone the velocity buffers (full-state checkpoints). Empty while the
    /// lazy allocation has not happened (or for stateless SGD).
    pub fn velocity_snapshot(&self) -> Vec<(Tensor, Tensor)> {
        self.velocity.clone()
    }

    /// Replace the velocity buffers wholesale (checkpoint restore; an empty
    /// vec resets to the pre-first-step state).
    pub fn set_velocity(&mut self, velocity: Vec<(Tensor, Tensor)>) {
        self.velocity = velocity;
    }

    fn ensure_velocity(&mut self, params: &[(Tensor, Tensor)]) {
        if self.velocity.len() != params.len() {
            self.velocity = params
                .iter()
                .map(|(w, b)| (Tensor::zeros(w.shape()), Tensor::zeros(b.shape())))
                .collect();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_param(v: f32) -> Vec<(Tensor, Tensor)> {
        vec![(
            Tensor::from_vec(&[1], vec![v]).unwrap(),
            Tensor::from_vec(&[1], vec![0.0]).unwrap(),
        )]
    }

    fn grad(g: f32) -> Vec<(Tensor, Tensor)> {
        vec![(
            Tensor::from_vec(&[1], vec![g]).unwrap(),
            Tensor::from_vec(&[1], vec![0.0]).unwrap(),
        )]
    }

    #[test]
    fn sgd_matches_manual() {
        let mut opt = ModuleOptimizer::new(OptimizerKind::Sgd);
        let mut p = one_param(1.0);
        opt.step(&mut p, &grad(2.0), 0.1, 0.5);
        assert!((p[0].0.data()[0] - (1.0 - 0.1 * 0.5 * 2.0)).abs() < 1e-7);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut opt = ModuleOptimizer::new(OptimizerKind::Momentum { beta: 0.5 });
        let mut p = one_param(0.0);
        // constant gradient 1: v = 1, 1.5, 1.75, ... -> steps grow toward 2x
        opt.step(&mut p, &grad(1.0), 0.1, 1.0); // w = -0.1
        opt.step(&mut p, &grad(1.0), 0.1, 1.0); // v=1.5, w = -0.25
        assert!((p[0].0.data()[0] - -0.25).abs() < 1e-6, "{}", p[0].0.data()[0]);
    }

    #[test]
    fn nesterov_takes_lookahead_step() {
        let mut opt = ModuleOptimizer::new(OptimizerKind::Nesterov { beta: 0.5 });
        let mut p = one_param(0.0);
        opt.step(&mut p, &grad(1.0), 0.1, 1.0); // v=1, step = g + βv = 1.5 -> w=-0.15
        assert!((p[0].0.data()[0] - -0.15).abs() < 1e-6);
    }

    #[test]
    fn momentum_beats_sgd_on_quadratic() {
        // minimize 0.5*w^2 (grad = w): momentum converges faster from w=1
        let run = |kind| {
            let mut opt = ModuleOptimizer::new(kind);
            let mut p = one_param(1.0);
            for _ in 0..30 {
                let g = grad(p[0].0.data()[0]);
                opt.step(&mut p, &g, 0.1, 1.0);
            }
            p[0].0.data()[0].abs()
        };
        let sgd = run(OptimizerKind::Sgd);
        let mom = run(OptimizerKind::Momentum { beta: 0.8 });
        assert!(mom < sgd, "momentum {mom} should beat sgd {sgd}");
    }

    #[test]
    fn parse_roundtrip() {
        for s in ["sgd", "momentum:0.9", "nesterov:0.85"] {
            let o = OptimizerKind::parse(s).unwrap();
            assert_eq!(OptimizerKind::parse(&o.describe()).unwrap(), o);
        }
        assert!(OptimizerKind::parse("adam").is_err());
        assert!(OptimizerKind::parse("momentum:x").is_err());
    }

    #[test]
    fn parse_is_lenient_about_case_and_whitespace() {
        assert_eq!(OptimizerKind::parse(" SGD ").unwrap(), OptimizerKind::Sgd);
        assert_eq!(
            OptimizerKind::parse("Momentum:0.9").unwrap(),
            OptimizerKind::Momentum { beta: 0.9 }
        );
        assert_eq!(
            OptimizerKind::parse(" NESTEROV:0.5 ").unwrap(),
            OptimizerKind::Nesterov { beta: 0.5 }
        );
    }

    #[test]
    fn parse_rejects_beta_outside_unit_interval() {
        assert!(OptimizerKind::parse("momentum:1.0").is_err());
        assert!(OptimizerKind::parse("momentum:-0.1").is_err());
        assert!(OptimizerKind::parse("nesterov:1.5").is_err());
        assert!(OptimizerKind::parse("nesterov:nan").is_err());
        assert!(OptimizerKind::parse("momentum:0.0").is_ok(), "0 is a valid (inert) beta");
    }
}
