//! Plain mini-batch SGD with classic backpropagation (eq. (3)/(4)) —
//! the textbook baseline the paper's centralized method (S=1, K=1) must
//! reproduce exactly, implemented independently of the pipeline machinery
//! so equivalence tests have a second opinion.

use crate::data::{Dataset, MiniBatchSampler};
use crate::nn::{self, LayerShape};
use crate::tensor::Tensor;

pub struct SgdBaseline {
    pub layers: Vec<LayerShape>,
    pub params: Vec<(Tensor, Tensor)>,
    sampler: MiniBatchSampler,
}

impl SgdBaseline {
    pub fn new(
        layers: Vec<LayerShape>,
        params: Vec<(Tensor, Tensor)>,
        sampler: MiniBatchSampler,
    ) -> SgdBaseline {
        SgdBaseline {
            layers,
            params,
            sampler,
        }
    }

    /// One SGD iteration; returns the mini-batch loss before the update.
    pub fn step(&mut self, ds: &Dataset, eta: f64) -> f32 {
        let (x, onehot) = self.sampler.sample_batch(ds);
        let (loss, grads) = nn::full_backward(&x, &onehot, &self.params, &self.layers);
        for ((w, b), (g_w, g_b)) in self.params.iter_mut().zip(&grads) {
            w.axpy(-(eta as f32), g_w);
            b.axpy(-(eta as f32), g_b);
        }
        loss
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{shard_even, synthetic::SyntheticSpec};
    use crate::nn::init::init_params;
    use crate::nn::resmlp_layers;
    use crate::util::rng::Pcg32;

    #[test]
    fn sgd_learns() {
        let ds = SyntheticSpec::small(200, 10, 3, 1).generate();
        let layers = resmlp_layers(10, 8, 1, 3);
        let mut rng = Pcg32::new(2);
        let params = init_params(&mut rng, &layers);
        let shard = shard_even(&ds, 1, 0).unwrap().remove(0);
        let sampler = MiniBatchSampler::new(shard, 16, 5);
        let mut sgd = SgdBaseline::new(layers, params, sampler);
        let first = sgd.step(&ds, 0.3);
        let mut last = first;
        for _ in 0..120 {
            last = sgd.step(&ds, 0.3);
        }
        assert!(last < first * 0.8, "{first} -> {last}");
    }
}
