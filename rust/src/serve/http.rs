//! Minimal HTTP/1.1 front for `sgs serve` — `std::net` only, no deps.
//!
//! Routes:
//!
//! * `POST /predict` — body `{"x": [[...]]}` (or a flat `{"x": [...]}`
//!   for a single row); replies
//!   `{"id": N, "argmax": [...], "scores": [[...]]}`. Ids are a
//!   per-connection sequence assigned by the server.
//! * `GET /metrics` — the serve process's
//!   [`MetricsRegistry`] snapshot as JSON (request
//!   latency histogram, batch occupancy, `serve_qps`, ...).
//! * `GET /healthz` — `{"ok": true}` liveness probe.
//!
//! Parsing is deliberately small: request line + headers, with only
//! `Content-Length` and `Connection` interpreted. Connections are
//! keep-alive by default (`Connection: close` honored); bodies are
//! capped at [`MAX_BODY`] bytes. Handler threads block on the socket
//! without a timeout, so an idle keep-alive connection lives until the
//! client closes it — the accept loop (not the handlers) is what watches
//! the shutdown flag.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::mpsc::Sender;
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::net::worker::shutdown_flag;
use crate::obs::{MetricsRegistry, WallClock};
use crate::serve::{enqueue_and_wait, ServeReply, ServeRequest};
use crate::tensor::Tensor;
use crate::util::json::Json;

/// Largest accepted request body (4 MiB — thousands of float rows).
pub const MAX_BODY: usize = 4 << 20;

/// One parsed request, enough for routing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpRequest {
    /// upper-cased method (`GET`, `POST`, ...)
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
    /// false iff the client sent `Connection: close`
    pub keep_alive: bool,
}

/// Read one request off the wire. `Ok(None)` is a clean EOF (client done
/// with the connection); errors are malformed requests or I/O failures.
pub fn read_request(r: &mut impl BufRead) -> Result<Option<HttpRequest>> {
    let mut line = String::new();
    let n = r
        .read_line(&mut line)
        .map_err(|e| Error::Net(format!("http read: {e}")))?;
    if n == 0 {
        return Ok(None);
    }
    let first = line.trim_end();
    let mut parts = first.split_whitespace();
    let method = parts.next().unwrap_or("").to_ascii_uppercase();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || path.is_empty() || !version.starts_with("HTTP/1.") {
        return Err(Error::Net(format!("malformed http request line {first:?}")));
    }
    let mut content_length = 0usize;
    let mut keep_alive = true;
    loop {
        let mut header = String::new();
        let n = r
            .read_line(&mut header)
            .map_err(|e| Error::Net(format!("http read: {e}")))?;
        if n == 0 {
            return Err(Error::Net("http connection closed mid-headers".into()));
        }
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            let value = value.trim();
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value
                    .parse()
                    .map_err(|_| Error::Net(format!("bad content-length {value:?}")))?;
            } else if name.eq_ignore_ascii_case("connection") {
                keep_alive = !value.eq_ignore_ascii_case("close");
            }
        }
    }
    if content_length > MAX_BODY {
        return Err(Error::Net(format!(
            "request body of {content_length} bytes exceeds the {MAX_BODY} byte cap"
        )));
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        r.read_exact(&mut body)
            .map_err(|e| Error::Net(format!("http body read: {e}")))?;
    }
    Ok(Some(HttpRequest {
        method,
        path,
        body,
        keep_alive,
    }))
}

/// Serialize one response (JSON content type throughout).
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    reason: &str,
    body: &str,
    keep_alive: bool,
) -> Result<()> {
    let connection = if keep_alive { "keep-alive" } else { "close" };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: {connection}\r\n\r\n",
        body.len()
    );
    w.write_all(head.as_bytes())
        .map_err(|e| Error::Net(format!("http write: {e}")))?;
    w.write_all(body.as_bytes())
        .map_err(|e| Error::Net(format!("http write: {e}")))?;
    w.flush().map_err(|e| Error::Net(format!("http flush: {e}")))
}

/// Decode a predict body: `{"x": [[f, ...], ...]}` rows, or a flat
/// `{"x": [f, ...]}` treated as one row.
pub fn tensor_from_json(doc: &Json) -> Result<Tensor> {
    let x = doc
        .opt("x")
        .ok_or_else(|| Error::Json("predict body needs an \"x\" array".into()))?;
    let arr = x
        .as_arr()
        .map_err(|_| Error::Json("\"x\" must be an array".into()))?;
    if arr.is_empty() {
        return Err(Error::Json("\"x\" must not be empty".into()));
    }
    let scalar = |v: &Json| -> Result<f32> {
        v.as_f64()
            .map(|f| f as f32)
            .map_err(|_| Error::Json("\"x\" entries must be numbers".into()))
    };
    let mut flat = Vec::new();
    let (rows, cols) = if arr.first().is_some_and(|v| v.as_arr().is_ok()) {
        let mut cols = 0usize;
        for row in arr {
            let row = row
                .as_arr()
                .map_err(|_| Error::Json("\"x\" rows must all be arrays".into()))?;
            if cols == 0 {
                cols = row.len();
            } else if row.len() != cols {
                return Err(Error::Json(format!(
                    "ragged \"x\": row of {} values after rows of {cols}",
                    row.len()
                )));
            }
            for v in row {
                flat.push(scalar(v)?);
            }
        }
        (arr.len(), cols)
    } else {
        for v in arr {
            flat.push(scalar(v)?);
        }
        (1, arr.len())
    };
    if cols == 0 {
        return Err(Error::Json("\"x\" rows must not be empty".into()));
    }
    Tensor::from_vec(&[rows, cols], flat)
}

/// Encode a reply as the `POST /predict` response body.
pub fn reply_to_json(rep: &ServeReply) -> Json {
    let shape = rep.scores.shape();
    let cols = shape.get(1).copied().unwrap_or(rep.scores.len());
    let rows: Vec<Json> = rep
        .scores
        .data()
        .chunks(cols.max(1))
        .map(|row| Json::from(row.iter().map(|&v| v as f64).collect::<Vec<f64>>()))
        .collect();
    let mut j = Json::obj();
    j.set("id", rep.id)
        .set(
            "argmax",
            Json::from(rep.argmax.iter().map(|&c| c as u64).collect::<Vec<u64>>()),
        )
        .set("scores", Json::Arr(rows));
    j
}

/// Accept HTTP connections until shutdown; each gets a detached handler
/// thread.
pub(crate) fn accept_http(
    listener: TcpListener,
    tx: Sender<ServeRequest>,
    clock: Arc<WallClock>,
    metrics: Arc<MetricsRegistry>,
) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    let flag = shutdown_flag();
    while !flag.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                if stream.set_nonblocking(false).is_err() {
                    continue;
                }
                let conn_tx = tx.clone();
                let conn_clock = Arc::clone(&clock);
                let conn_metrics = Arc::clone(&metrics);
                let spawned = std::thread::Builder::new()
                    .name("serve-http".into())
                    .spawn(move || {
                        let _ = handle_conn(stream, &conn_tx, &conn_clock, &conn_metrics);
                    });
                if spawned.is_err() {
                    continue;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(super::IDLE_POLL);
            }
            Err(_) => std::thread::sleep(super::IDLE_POLL),
        }
    }
}

/// One keep-alive connection: read requests until EOF or
/// `Connection: close`.
fn handle_conn(
    stream: TcpStream,
    tx: &Sender<ServeRequest>,
    clock: &WallClock,
    metrics: &MetricsRegistry,
) -> Result<()> {
    let read_half = stream
        .try_clone()
        .map_err(|e| Error::Net(format!("http clone stream: {e}")))?;
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    let mut next_id = 0u64;
    loop {
        let req = match read_request(&mut reader) {
            Ok(Some(req)) => req,
            Ok(None) => return Ok(()),
            Err(e) => {
                let body = error_body(&e);
                write_response(&mut writer, 400, "Bad Request", &body, false)?;
                return Ok(());
            }
        };
        let keep_alive = req.keep_alive;
        let (status, reason, body) = route(&req, tx, clock, metrics, &mut next_id);
        write_response(&mut writer, status, reason, &body, keep_alive)?;
        if !keep_alive {
            return Ok(());
        }
    }
}

fn error_body(e: &Error) -> String {
    let mut j = Json::obj();
    j.set("error", format!("{e}"));
    j.to_string_compact()
}

/// Dispatch one request to its handler.
fn route(
    req: &HttpRequest,
    tx: &Sender<ServeRequest>,
    clock: &WallClock,
    metrics: &MetricsRegistry,
    next_id: &mut u64,
) -> (u16, &'static str, String) {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/predict") => match predict(req, tx, clock, next_id) {
            Ok(body) => (200, "OK", body),
            Err(e) => (400, "Bad Request", error_body(&e)),
        },
        ("GET", "/metrics") => (200, "OK", metrics.to_json().to_string_compact()),
        ("GET", "/healthz") => (200, "OK", "{\"ok\":true}".into()),
        _ => {
            let e = Error::Net(format!("no route for {} {}", req.method, req.path));
            (404, "Not Found", error_body(&e))
        }
    }
}

fn predict(
    req: &HttpRequest,
    tx: &Sender<ServeRequest>,
    clock: &WallClock,
    next_id: &mut u64,
) -> Result<String> {
    let text = std::str::from_utf8(&req.body)
        .map_err(|_| Error::Json("predict body is not UTF-8".into()))?;
    let doc = Json::parse(text)?;
    let x = tensor_from_json(&doc)?;
    let id = *next_id;
    *next_id = next_id.wrapping_add(1);
    let rep = enqueue_and_wait(tx, clock, id, x)?;
    Ok(reply_to_json(&rep).to_string_compact())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn req(text: &str) -> Result<Option<HttpRequest>> {
        read_request(&mut Cursor::new(text.as_bytes().to_vec()))
    }

    #[test]
    fn parses_post_with_body_and_connection_close() {
        let r = req(
            "POST /predict HTTP/1.1\r\nHost: x\r\nContent-Length: 11\r\nConnection: close\r\n\r\n{\"x\":[1,2]}",
        )
        .unwrap()
        .unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/predict");
        assert_eq!(r.body, b"{\"x\":[1,2]}");
        assert!(!r.keep_alive);
    }

    #[test]
    fn get_defaults_to_keep_alive_with_empty_body() {
        let r = req("GET /healthz HTTP/1.1\r\n\r\n").unwrap().unwrap();
        assert_eq!(r.method, "GET");
        assert!(r.keep_alive);
        assert!(r.body.is_empty());
    }

    #[test]
    fn eof_is_none_and_garbage_is_an_error() {
        assert!(req("").unwrap().is_none());
        assert!(req("nonsense\r\n\r\n").is_err());
        assert!(req("GET /x HTTP/1.1\r\nContent-Length: zork\r\n\r\n").is_err());
        let truncated = "POST /p HTTP/1.1\r\nContent-Length: 99\r\n\r\nshort";
        assert!(req(truncated).is_err());
        let huge = format!("POST /p HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1);
        assert!(req(&huge).is_err());
    }

    #[test]
    fn two_pipelined_requests_parse_in_order() {
        let text = "GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\nConnection: close\r\n\r\n";
        let mut c = Cursor::new(text.as_bytes().to_vec());
        let a = read_request(&mut c).unwrap().unwrap();
        let b = read_request(&mut c).unwrap().unwrap();
        assert_eq!((a.path.as_str(), a.keep_alive), ("/a", true));
        assert_eq!((b.path.as_str(), b.keep_alive), ("/b", false));
        assert!(read_request(&mut c).unwrap().is_none());
    }

    #[test]
    fn response_writer_emits_status_and_length() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "OK", "{\"ok\":true}", true).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Length: 11\r\n"));
        assert!(text.contains("Connection: keep-alive\r\n"));
        assert!(text.ends_with("\r\n\r\n{\"ok\":true}"));
    }

    #[test]
    fn tensor_from_json_accepts_rows_and_flat() {
        let doc = Json::parse("{\"x\": [[1, 2, 3], [4, 5, 6]]}").unwrap();
        let t = tensor_from_json(&doc).unwrap();
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.data(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);

        let doc = Json::parse("{\"x\": [1.5, -2.0]}").unwrap();
        let t = tensor_from_json(&doc).unwrap();
        assert_eq!(t.shape(), &[1, 2]);

        assert!(tensor_from_json(&Json::parse("{}").unwrap()).is_err());
        assert!(tensor_from_json(&Json::parse("{\"x\": []}").unwrap()).is_err());
        assert!(tensor_from_json(&Json::parse("{\"x\": [[1],[2,3]]}").unwrap()).is_err());
        assert!(tensor_from_json(&Json::parse("{\"x\": [[]]}").unwrap()).is_err());
        assert!(tensor_from_json(&Json::parse("{\"x\": [\"a\"]}").unwrap()).is_err());
    }

    #[test]
    fn reply_round_trips_to_json() {
        let rep = ServeReply {
            id: 9,
            argmax: vec![2, 0],
            scores: Tensor::from_vec(&[2, 3], vec![0.1, 0.2, 0.7, 0.8, 0.1, 0.1]).unwrap(),
        };
        let j = reply_to_json(&rep);
        assert_eq!(j.get("id").unwrap().as_usize().unwrap(), 9);
        let argmax = j.get("argmax").unwrap().as_arr().unwrap();
        assert_eq!(argmax.len(), 2);
        assert_eq!(argmax[0].as_usize().unwrap(), 2);
        let scores = j.get("scores").unwrap().as_arr().unwrap();
        assert_eq!(scores.len(), 2);
        assert_eq!(scores[1].as_arr().unwrap().len(), 3);
        let trip = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(trip.get("id").unwrap().as_usize().unwrap(), 9);
    }
}
