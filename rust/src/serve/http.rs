//! Minimal HTTP/1.1 front for `sgs serve` — `std::net` only, no deps.
//!
//! Routes:
//!
//! * `POST /predict` — body `{"x": [[...]]}` (or a flat `{"x": [...]}`
//!   for a single row); replies
//!   `{"id": N, "argmax": [...], "scores": [[...]]}`. Ids are a
//!   per-connection sequence assigned by the server.
//! * `GET /metrics` — the serve process's [`MetricsRegistry`] in
//!   Prometheus text-exposition format via [`crate::obs::prom::encode`]
//!   — the same encoder the training status server
//!   (`crate::monitor`) mounts, so both planes emit byte-identical
//!   expositions (request latency histogram, batch occupancy,
//!   `serve_qps`, ...).
//! * `GET /status` — JSON serving summary: uptime, request/error/batch
//!   totals, `serve_qps`, and latency p50/p95/p99 derived with
//!   [`Histogram::quantile`](crate::obs::Histogram::quantile).
//! * `GET /healthz` — `{"ok": true}` liveness probe.
//!
//! The request/response primitives ([`read_request`], [`write_response`],
//! [`read_response`], [`http_get`]) are shared with the training status
//! front and `sgs top`.
//!
//! Parsing is deliberately small: request line + headers, with only
//! `Content-Length` and `Connection` interpreted. Connections are
//! keep-alive by default (`Connection: close` honored); bodies are
//! capped at [`MAX_BODY`] bytes. Handler threads block on the socket
//! without a timeout, so an idle keep-alive connection lives until the
//! client closes it — the accept loop (not the handlers) is what watches
//! the shutdown flag.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::mpsc::Sender;
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::net::worker::shutdown_flag;
use crate::obs::{MetricsRegistry, WallClock};
use crate::serve::{enqueue_and_wait, ServeReply, ServeRequest};
use crate::tensor::Tensor;
use crate::util::json::Json;

/// Largest accepted request body (4 MiB — thousands of float rows).
pub const MAX_BODY: usize = 4 << 20;

/// One parsed request, enough for routing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpRequest {
    /// upper-cased method (`GET`, `POST`, ...)
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
    /// false iff the client sent `Connection: close`
    pub keep_alive: bool,
}

/// Read one request off the wire. `Ok(None)` is a clean EOF (client done
/// with the connection); errors are malformed requests or I/O failures.
pub fn read_request(r: &mut impl BufRead) -> Result<Option<HttpRequest>> {
    let mut line = String::new();
    let n = r
        .read_line(&mut line)
        .map_err(|e| Error::Net(format!("http read: {e}")))?;
    if n == 0 {
        return Ok(None);
    }
    let first = line.trim_end();
    let mut parts = first.split_whitespace();
    let method = parts.next().unwrap_or("").to_ascii_uppercase();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || path.is_empty() || !version.starts_with("HTTP/1.") {
        return Err(Error::Net(format!("malformed http request line {first:?}")));
    }
    let mut content_length = 0usize;
    let mut keep_alive = true;
    loop {
        let mut header = String::new();
        let n = r
            .read_line(&mut header)
            .map_err(|e| Error::Net(format!("http read: {e}")))?;
        if n == 0 {
            return Err(Error::Net("http connection closed mid-headers".into()));
        }
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            let value = value.trim();
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value
                    .parse()
                    .map_err(|_| Error::Net(format!("bad content-length {value:?}")))?;
            } else if name.eq_ignore_ascii_case("connection") {
                keep_alive = !value.eq_ignore_ascii_case("close");
            }
        }
    }
    if content_length > MAX_BODY {
        return Err(Error::Net(format!(
            "request body of {content_length} bytes exceeds the {MAX_BODY} byte cap"
        )));
    }
    let mut body = vec![0u8; content_length];
    if content_length > 0 {
        r.read_exact(&mut body)
            .map_err(|e| Error::Net(format!("http body read: {e}")))?;
    }
    Ok(Some(HttpRequest {
        method,
        path,
        body,
        keep_alive,
    }))
}

/// Serialize one response with a JSON content type (most routes).
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    reason: &str,
    body: &str,
    keep_alive: bool,
) -> Result<()> {
    write_response_typed(w, status, reason, "application/json", body, keep_alive)
}

/// The Prometheus text content type `/metrics` responses carry.
pub const PROMETHEUS_CONTENT_TYPE: &str = "text/plain; version=0.0.4";

/// Serialize one response with an explicit content type (`/metrics`
/// serves Prometheus text, everything else JSON).
pub fn write_response_typed(
    w: &mut impl Write,
    status: u16,
    reason: &str,
    content_type: &str,
    body: &str,
    keep_alive: bool,
) -> Result<()> {
    let connection = if keep_alive { "keep-alive" } else { "close" };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: {connection}\r\n\r\n",
        body.len()
    );
    w.write_all(head.as_bytes())
        .map_err(|e| Error::Net(format!("http write: {e}")))?;
    w.write_all(body.as_bytes())
        .map_err(|e| Error::Net(format!("http write: {e}")))?;
    w.flush().map_err(|e| Error::Net(format!("http flush: {e}")))
}

/// Read one HTTP/1.1 response off the wire (client side): status code
/// plus UTF-8 body. Only `Content-Length` framing is understood — the
/// sgs servers always send it.
pub fn read_response(r: &mut impl BufRead) -> Result<(u16, String)> {
    let mut line = String::new();
    r.read_line(&mut line)
        .map_err(|e| Error::Net(format!("http read: {e}")))?;
    let status_line = line.trim_end();
    let code: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .ok_or_else(|| Error::Net(format!("malformed http status line {status_line:?}")))?;
    let mut content_length: Option<usize> = None;
    loop {
        let mut header = String::new();
        let n = r
            .read_line(&mut header)
            .map_err(|e| Error::Net(format!("http read: {e}")))?;
        if n == 0 {
            return Err(Error::Net("http connection closed mid-headers".into()));
        }
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                let parsed = value
                    .trim()
                    .parse()
                    .map_err(|_| Error::Net(format!("bad content-length {value:?}")))?;
                content_length = Some(parsed);
            }
        }
    }
    let body = match content_length {
        Some(len) if len > MAX_BODY => {
            return Err(Error::Net(format!(
                "response body of {len} bytes exceeds the {MAX_BODY} byte cap"
            )))
        }
        Some(len) => {
            let mut buf = vec![0u8; len];
            r.read_exact(&mut buf)
                .map_err(|e| Error::Net(format!("http body read: {e}")))?;
            buf
        }
        None => {
            let mut buf = Vec::new();
            r.read_to_end(&mut buf)
                .map_err(|e| Error::Net(format!("http body read: {e}")))?;
            buf
        }
    };
    let body = String::from_utf8(body)
        .map_err(|_| Error::Net("http response body is not UTF-8".into()))?;
    Ok((code, body))
}

/// One-shot GET against `addr` (e.g. `127.0.0.1:9100`): connect, request
/// `path` with `Connection: close`, return `(status, body)`. The polling
/// client behind `sgs top` and the smoke tests.
pub fn http_get(addr: &str, path: &str, timeout: std::time::Duration) -> Result<(u16, String)> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| Error::Net(format!("connect {addr}: {e}")))?;
    stream
        .set_read_timeout(Some(timeout))
        .map_err(|e| Error::Net(format!("set timeout: {e}")))?;
    stream
        .set_write_timeout(Some(timeout))
        .map_err(|e| Error::Net(format!("set timeout: {e}")))?;
    let mut writer = stream
        .try_clone()
        .map_err(|e| Error::Net(format!("clone stream: {e}")))?;
    writer
        .write_all(format!("GET {path} HTTP/1.1\r\nHost: sgs\r\nConnection: close\r\n\r\n").as_bytes())
        .map_err(|e| Error::Net(format!("http write: {e}")))?;
    writer.flush().map_err(|e| Error::Net(format!("http flush: {e}")))?;
    read_response(&mut BufReader::new(stream))
}

/// Decode a predict body: `{"x": [[f, ...], ...]}` rows, or a flat
/// `{"x": [f, ...]}` treated as one row.
pub fn tensor_from_json(doc: &Json) -> Result<Tensor> {
    let x = doc
        .opt("x")
        .ok_or_else(|| Error::Json("predict body needs an \"x\" array".into()))?;
    let arr = x
        .as_arr()
        .map_err(|_| Error::Json("\"x\" must be an array".into()))?;
    if arr.is_empty() {
        return Err(Error::Json("\"x\" must not be empty".into()));
    }
    let scalar = |v: &Json| -> Result<f32> {
        v.as_f64()
            .map(|f| f as f32)
            .map_err(|_| Error::Json("\"x\" entries must be numbers".into()))
    };
    let mut flat = Vec::new();
    let (rows, cols) = if arr.first().is_some_and(|v| v.as_arr().is_ok()) {
        let mut cols = 0usize;
        for row in arr {
            let row = row
                .as_arr()
                .map_err(|_| Error::Json("\"x\" rows must all be arrays".into()))?;
            if cols == 0 {
                cols = row.len();
            } else if row.len() != cols {
                return Err(Error::Json(format!(
                    "ragged \"x\": row of {} values after rows of {cols}",
                    row.len()
                )));
            }
            for v in row {
                flat.push(scalar(v)?);
            }
        }
        (arr.len(), cols)
    } else {
        for v in arr {
            flat.push(scalar(v)?);
        }
        (1, arr.len())
    };
    if cols == 0 {
        return Err(Error::Json("\"x\" rows must not be empty".into()));
    }
    Tensor::from_vec(&[rows, cols], flat)
}

/// Encode a reply as the `POST /predict` response body.
pub fn reply_to_json(rep: &ServeReply) -> Json {
    let shape = rep.scores.shape();
    let cols = shape.get(1).copied().unwrap_or(rep.scores.len());
    let rows: Vec<Json> = rep
        .scores
        .data()
        .chunks(cols.max(1))
        .map(|row| Json::from(row.iter().map(|&v| v as f64).collect::<Vec<f64>>()))
        .collect();
    let mut j = Json::obj();
    j.set("id", rep.id)
        .set(
            "argmax",
            Json::from(rep.argmax.iter().map(|&c| c as u64).collect::<Vec<u64>>()),
        )
        .set("scores", Json::Arr(rows));
    j
}

/// Accept HTTP connections until shutdown; each gets a detached handler
/// thread.
pub(crate) fn accept_http(
    listener: TcpListener,
    tx: Sender<ServeRequest>,
    clock: Arc<WallClock>,
    metrics: Arc<MetricsRegistry>,
) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    let flag = shutdown_flag();
    while !flag.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                if stream.set_nonblocking(false).is_err() {
                    continue;
                }
                let conn_tx = tx.clone();
                let conn_clock = Arc::clone(&clock);
                let conn_metrics = Arc::clone(&metrics);
                let spawned = std::thread::Builder::new()
                    .name("serve-http".into())
                    .spawn(move || {
                        let _ = handle_conn(stream, &conn_tx, &conn_clock, &conn_metrics);
                    });
                if spawned.is_err() {
                    continue;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(super::IDLE_POLL);
            }
            Err(_) => std::thread::sleep(super::IDLE_POLL),
        }
    }
}

/// One keep-alive connection: read requests until EOF or
/// `Connection: close`.
fn handle_conn(
    stream: TcpStream,
    tx: &Sender<ServeRequest>,
    clock: &WallClock,
    metrics: &MetricsRegistry,
) -> Result<()> {
    let read_half = stream
        .try_clone()
        .map_err(|e| Error::Net(format!("http clone stream: {e}")))?;
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    let mut next_id = 0u64;
    loop {
        let req = match read_request(&mut reader) {
            Ok(Some(req)) => req,
            Ok(None) => return Ok(()),
            Err(e) => {
                let body = error_body(&e);
                write_response(&mut writer, 400, "Bad Request", &body, false)?;
                return Ok(());
            }
        };
        let keep_alive = req.keep_alive;
        let (status, reason, content_type, body) = route(&req, tx, clock, metrics, &mut next_id);
        write_response_typed(&mut writer, status, reason, content_type, &body, keep_alive)?;
        if !keep_alive {
            return Ok(());
        }
    }
}

fn error_body(e: &Error) -> String {
    let mut j = Json::obj();
    j.set("error", format!("{e}"));
    j.to_string_compact()
}

/// Dispatch one request to its handler: `(status, reason, content type,
/// body)`.
fn route(
    req: &HttpRequest,
    tx: &Sender<ServeRequest>,
    clock: &WallClock,
    metrics: &MetricsRegistry,
    next_id: &mut u64,
) -> (u16, &'static str, &'static str, String) {
    const JSON: &str = "application/json";
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/predict") => match predict(req, tx, clock, next_id) {
            Ok(body) => (200, "OK", JSON, body),
            Err(e) => (400, "Bad Request", JSON, error_body(&e)),
        },
        ("GET", "/metrics") => {
            (200, "OK", PROMETHEUS_CONTENT_TYPE, crate::obs::prom::encode(metrics))
        }
        ("GET", "/status") => (200, "OK", JSON, serve_status_json(clock, metrics)),
        ("GET", "/healthz") => (200, "OK", JSON, "{\"ok\":true}".into()),
        _ => {
            let e = Error::Net(format!("no route for {} {}", req.method, req.path));
            (404, "Not Found", JSON, error_body(&e))
        }
    }
}

/// `GET /status` on a serve instance: the JSON summary `sgs top` renders
/// QPS/latency panels from. Latency quantiles come from the shared
/// fixed-bucket estimator, not raw bucket dumps.
fn serve_status_json(clock: &WallClock, metrics: &MetricsRegistry) -> String {
    // JSON has no NaN: an empty histogram's quantiles serialize as null.
    // All lookups are non-creating so a status poll racing engine
    // startup can't register instruments first.
    let quantile_json = |h: Option<&Arc<crate::obs::Histogram>>, q: f64| match h
        .and_then(|h| h.quantile(q))
    {
        Some(v) if v.is_finite() => Json::from(v),
        _ => Json::Null,
    };
    let counter = |name: &str| metrics.find_counter(name).map(|c| c.get()).unwrap_or(0);
    let latency = metrics.find_histogram("serve_latency_us");
    let mut lat = Json::obj();
    lat.set("count", latency.as_ref().map(|h| h.count()).unwrap_or(0))
        .set("mean_us", latency.as_ref().map(|h| h.mean()).unwrap_or(0.0))
        .set("p50_us", quantile_json(latency.as_ref(), 0.50))
        .set("p95_us", quantile_json(latency.as_ref(), 0.95))
        .set("p99_us", quantile_json(latency.as_ref(), 0.99));
    let mut j = Json::obj();
    j.set("schema", "sgs-status/v1")
        .set("role", "serve")
        .set("uptime_s", clock.elapsed_s())
        .set("requests_total", counter("serve_requests_total"))
        .set("errors_total", counter("serve_errors_total"))
        .set("batches_total", counter("serve_batches_total"))
        .set("qps", metrics.find_gauge("serve_qps").map(|g| g.get()).unwrap_or(0.0))
        .set("latency", lat);
    j.to_string_compact()
}

fn predict(
    req: &HttpRequest,
    tx: &Sender<ServeRequest>,
    clock: &WallClock,
    next_id: &mut u64,
) -> Result<String> {
    let text = std::str::from_utf8(&req.body)
        .map_err(|_| Error::Json("predict body is not UTF-8".into()))?;
    let doc = Json::parse(text)?;
    let x = tensor_from_json(&doc)?;
    let id = *next_id;
    *next_id = next_id.wrapping_add(1);
    let rep = enqueue_and_wait(tx, clock, id, x)?;
    Ok(reply_to_json(&rep).to_string_compact())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn req(text: &str) -> Result<Option<HttpRequest>> {
        read_request(&mut Cursor::new(text.as_bytes().to_vec()))
    }

    #[test]
    fn parses_post_with_body_and_connection_close() {
        let r = req(
            "POST /predict HTTP/1.1\r\nHost: x\r\nContent-Length: 11\r\nConnection: close\r\n\r\n{\"x\":[1,2]}",
        )
        .unwrap()
        .unwrap();
        assert_eq!(r.method, "POST");
        assert_eq!(r.path, "/predict");
        assert_eq!(r.body, b"{\"x\":[1,2]}");
        assert!(!r.keep_alive);
    }

    #[test]
    fn get_defaults_to_keep_alive_with_empty_body() {
        let r = req("GET /healthz HTTP/1.1\r\n\r\n").unwrap().unwrap();
        assert_eq!(r.method, "GET");
        assert!(r.keep_alive);
        assert!(r.body.is_empty());
    }

    #[test]
    fn eof_is_none_and_garbage_is_an_error() {
        assert!(req("").unwrap().is_none());
        assert!(req("nonsense\r\n\r\n").is_err());
        assert!(req("GET /x HTTP/1.1\r\nContent-Length: zork\r\n\r\n").is_err());
        let truncated = "POST /p HTTP/1.1\r\nContent-Length: 99\r\n\r\nshort";
        assert!(req(truncated).is_err());
        let huge = format!("POST /p HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1);
        assert!(req(&huge).is_err());
    }

    #[test]
    fn two_pipelined_requests_parse_in_order() {
        let text = "GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\nConnection: close\r\n\r\n";
        let mut c = Cursor::new(text.as_bytes().to_vec());
        let a = read_request(&mut c).unwrap().unwrap();
        let b = read_request(&mut c).unwrap().unwrap();
        assert_eq!((a.path.as_str(), a.keep_alive), ("/a", true));
        assert_eq!((b.path.as_str(), b.keep_alive), ("/b", false));
        assert!(read_request(&mut c).unwrap().is_none());
    }

    #[test]
    fn response_writer_emits_status_and_length() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "OK", "{\"ok\":true}", true).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Length: 11\r\n"));
        assert!(text.contains("Connection: keep-alive\r\n"));
        assert!(text.ends_with("\r\n\r\n{\"ok\":true}"));
    }

    #[test]
    fn tensor_from_json_accepts_rows_and_flat() {
        let doc = Json::parse("{\"x\": [[1, 2, 3], [4, 5, 6]]}").unwrap();
        let t = tensor_from_json(&doc).unwrap();
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.data(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);

        let doc = Json::parse("{\"x\": [1.5, -2.0]}").unwrap();
        let t = tensor_from_json(&doc).unwrap();
        assert_eq!(t.shape(), &[1, 2]);

        assert!(tensor_from_json(&Json::parse("{}").unwrap()).is_err());
        assert!(tensor_from_json(&Json::parse("{\"x\": []}").unwrap()).is_err());
        assert!(tensor_from_json(&Json::parse("{\"x\": [[1],[2,3]]}").unwrap()).is_err());
        assert!(tensor_from_json(&Json::parse("{\"x\": [[]]}").unwrap()).is_err());
        assert!(tensor_from_json(&Json::parse("{\"x\": [\"a\"]}").unwrap()).is_err());
    }

    #[test]
    fn metrics_route_uses_the_shared_prometheus_encoder_byte_for_byte() {
        use std::sync::mpsc;
        let metrics = MetricsRegistry::new();
        metrics.counter("serve_requests_total").add(3);
        metrics.gauge("serve_qps").set(12.5);
        metrics.histogram("serve_latency_us", &[100.0, 1000.0]).observe(250.0);
        let (tx, _rx) = mpsc::channel();
        let clock = WallClock::new();
        let req = HttpRequest {
            method: "GET".into(),
            path: "/metrics".into(),
            body: Vec::new(),
            keep_alive: false,
        };
        let (status, _, content_type, body) = route(&req, &tx, &clock, &metrics, &mut 0);
        assert_eq!(status, 200);
        assert_eq!(content_type, PROMETHEUS_CONTENT_TYPE);
        // byte-equality with the shared encoder: serve and the training
        // status server must emit the identical exposition format
        assert_eq!(body, crate::obs::prom::encode(&metrics));
        assert!(body.contains("# TYPE serve_latency_us histogram"), "{body}");
    }

    #[test]
    fn status_route_reports_latency_quantiles() {
        use std::sync::mpsc;
        let metrics = MetricsRegistry::new();
        metrics.counter("serve_requests_total").add(8);
        let h = metrics.histogram("serve_latency_us", &[100.0, 1000.0]);
        for _ in 0..4 {
            h.observe(50.0);
        }
        let (tx, _rx) = mpsc::channel();
        let clock = WallClock::new();
        let req = HttpRequest {
            method: "GET".into(),
            path: "/status".into(),
            body: Vec::new(),
            keep_alive: false,
        };
        let (status, _, content_type, body) = route(&req, &tx, &clock, &metrics, &mut 0);
        assert_eq!((status, content_type), (200, "application/json"));
        let doc = Json::parse(&body).unwrap();
        assert_eq!(doc.get("role").unwrap().as_str().unwrap(), "serve");
        assert_eq!(doc.get("requests_total").unwrap().as_usize().unwrap(), 8);
        let lat = doc.get("latency").unwrap();
        assert_eq!(lat.get("count").unwrap().as_usize().unwrap(), 4);
        assert!(lat.get("p50_us").unwrap().as_f64().unwrap() <= 100.0);
    }

    #[test]
    fn status_route_on_an_empty_registry_serves_nulls_not_nan() {
        use std::sync::mpsc;
        let metrics = MetricsRegistry::new();
        let (tx, _rx) = mpsc::channel();
        let clock = WallClock::new();
        let req = HttpRequest {
            method: "GET".into(),
            path: "/status".into(),
            body: Vec::new(),
            keep_alive: false,
        };
        let (status, _, _, body) = route(&req, &tx, &clock, &metrics, &mut 0);
        assert_eq!(status, 200);
        let doc = Json::parse(&body).expect("valid JSON even with empty metrics");
        let p50 = doc.get("latency").unwrap().get("p50_us").unwrap();
        assert!(p50.as_f64().is_err(), "empty histogram p50 should be null, got {p50:?}");
        // the read-only path must not have created any instruments
        assert_eq!(metrics.instrument_counts(), (0, 0, 0));
    }

    #[test]
    fn client_read_response_parses_status_and_content_length_body() {
        let mut out = Vec::new();
        write_response_typed(&mut out, 503, "Service Unavailable", "text/plain", "down", false)
            .unwrap();
        let (code, body) = read_response(&mut Cursor::new(out)).unwrap();
        assert_eq!((code, body.as_str()), (503, "down"));
        // read-to-EOF fallback when no Content-Length is present
        let raw = b"HTTP/1.1 200 OK\r\n\r\nhello".to_vec();
        let (code, body) = read_response(&mut Cursor::new(raw)).unwrap();
        assert_eq!((code, body.as_str()), (200, "hello"));
        assert!(read_response(&mut Cursor::new(b"garbage\r\n\r\n".to_vec())).is_err());
    }

    #[test]
    fn reply_round_trips_to_json() {
        let rep = ServeReply {
            id: 9,
            argmax: vec![2, 0],
            scores: Tensor::from_vec(&[2, 3], vec![0.1, 0.2, 0.7, 0.8, 0.1, 0.1]).unwrap(),
        };
        let j = reply_to_json(&rep);
        assert_eq!(j.get("id").unwrap().as_usize().unwrap(), 9);
        let argmax = j.get("argmax").unwrap().as_arr().unwrap();
        assert_eq!(argmax.len(), 2);
        assert_eq!(argmax[0].as_usize().unwrap(), 2);
        let scores = j.get("scores").unwrap().as_arr().unwrap();
        assert_eq!(scores.len(), 2);
        assert_eq!(scores[1].as_arr().unwrap().len(), 3);
        let trip = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(trip.get("id").unwrap().as_usize().unwrap(), 9);
    }
}
