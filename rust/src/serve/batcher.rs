//! The dynamic batcher's compute core: many queued requests, ONE padded
//! forward pass.
//!
//! [`BatchEngine`] owns a [`Predictor`] plus every buffer the serve hot
//! path touches — a `[max_batch, d_in]` staging input, the logits, the
//! softmax scores, and the per-row argmax. The input is always forwarded
//! at the FULL `max_batch` row count (partial batches ride with padding
//! rows), so activation shapes never change and the steady state
//! allocates nothing (`tests/alloc_guard.rs`). Padding is free to hold
//! stale rows: every kernel in the stack is per-row with a fixed
//! ascending-k accumulation order, so a request row's logits are bitwise
//! identical whatever occupies the other rows — which is also what makes
//! co-batching unrelated requests safe ([`crate::session::Predictor`]
//! pins this with a test).

use std::sync::mpsc::Sender;

use crate::error::{Error, Result};
use crate::session::Predictor;
use crate::steady_state;
use crate::tensor::Tensor;

/// One queued inference request, as staged by a front (Transport or HTTP).
pub struct ServeRequest {
    /// request id, echoed on the reply
    pub id: u64,
    /// feature rows, `[n, d_in]` with 1 ≤ n ≤ max_batch
    pub x: Tensor,
    /// where the demuxed answer goes (the front blocks on the other end)
    pub reply: Sender<Result<ServeReply>>,
    /// enqueue timestamp in µs on the server's clock (latency histogram)
    pub enqueued_us: u64,
}

/// The demuxed answer for one request.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeReply {
    pub id: u64,
    /// winning class per request row
    pub argmax: Vec<u32>,
    /// `[n, classes]` softmax scores
    pub scores: Tensor,
}

/// The serve loop's compute state: predictor + preallocated workspaces.
pub struct BatchEngine {
    predictor: Predictor,
    /// `[max_batch, d_in]` staging input (padding rows beyond the staged
    /// count are forwarded but their outputs ignored)
    input: Tensor,
    /// `[max_batch, classes]` raw logits of the last forward
    logits: Tensor,
    /// `[max_batch, classes]` softmax of the last forward's logits
    scores: Tensor,
    /// winning class per row of the last forward
    argmax: Vec<u32>,
    max_batch: usize,
    d_in: usize,
    classes: usize,
}

impl BatchEngine {
    /// Wrap a predictor and warm every workspace with one full-size
    /// forward pass, so the first real request already runs allocation-free.
    pub fn new(predictor: Predictor, max_batch: usize) -> Result<BatchEngine> {
        if max_batch == 0 {
            return Err(Error::Config("serve max_batch must be >= 1".into()));
        }
        let d_in = predictor.d_in();
        let classes = predictor.classes();
        if d_in == 0 || classes == 0 {
            return Err(Error::Config("predictor has an empty layer stack".into()));
        }
        let mut engine = BatchEngine {
            predictor,
            input: Tensor::zeros(&[max_batch, d_in]),
            logits: Tensor::empty(),
            scores: Tensor::zeros(&[max_batch, classes]),
            argmax: vec![0; max_batch],
            max_batch,
            d_in,
            classes,
        };
        engine.forward(max_batch)?;
        Ok(engine)
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    pub fn d_in(&self) -> usize {
        self.d_in
    }

    pub fn classes(&self) -> usize {
        self.classes
    }

    pub fn predictor(&self) -> &Predictor {
        &self.predictor
    }

    /// Copy a request's rows into the staging input starting at row `off`.
    /// Returns the number of rows staged.
    pub fn stage(&mut self, off: usize, x: &Tensor) -> Result<usize> {
        let shape = x.shape();
        if shape.len() != 2 || shape[1] != self.d_in {
            return Err(Error::Shape(format!(
                "request rows must be [n, {}], got {shape:?}",
                self.d_in
            )));
        }
        let n = shape[0];
        if n == 0 || off + n > self.max_batch {
            return Err(Error::Shape(format!(
                "request of {n} rows at offset {off} overflows max_batch {}",
                self.max_batch
            )));
        }
        let dst = self
            .input
            .data_mut()
            .get_mut(off * self.d_in..(off + n) * self.d_in)
            .ok_or_else(|| Error::Shape("staging input out of range".into()))?;
        dst.copy_from_slice(x.data());
        Ok(n)
    }

    /// Run the staged input through the model and fill `scores`/`argmax`
    /// for rows `[0, n)`. The forward always covers the full padded
    /// `max_batch` rows — constant shapes keep the workspaces fixed, and
    /// per-row kernels make the padding invisible to the live rows.
    /// Marked `#[steady_state]`: the lint keeps this body allocation-free.
    #[steady_state]
    pub fn forward(&mut self, n: usize) -> Result<()> {
        if n == 0 || n > self.max_batch {
            // static message: this body is #[steady_state], format! would
            // allocate on the hot path
            return Err(Error::Shape(
                "forward row count outside [1, max_batch]".into(),
            ));
        }
        self.predictor.predict_into(&self.input, &mut self.logits)?;
        for row in 0..n {
            let lo = row * self.classes;
            let hi = lo + self.classes;
            let logits = self
                .logits
                .data()
                .get(lo..hi)
                .ok_or_else(|| Error::Shape("logits shorter than staged rows".into()))?;
            let scores = self
                .scores
                .data_mut()
                .get_mut(lo..hi)
                .ok_or_else(|| Error::Shape("score buffer shorter than staged rows".into()))?;
            // stable softmax + argmax in one sweep, written in place
            let mut best = 0usize;
            let mut max = f32::NEG_INFINITY;
            for (j, &v) in logits.iter().enumerate() {
                if v > max {
                    max = v;
                    best = j;
                }
            }
            let mut sum = 0.0f32;
            for (dst, &v) in scores.iter_mut().zip(logits) {
                let e = (v - max).exp();
                *dst = e;
                sum += e;
            }
            let inv = 1.0 / sum;
            for dst in scores.iter_mut() {
                *dst *= inv;
            }
            if let Some(slot) = self.argmax.get_mut(row) {
                *slot = best as u32;
            }
        }
        Ok(())
    }

    /// Winning classes of the last [`BatchEngine::forward`], first `n` rows
    /// valid.
    pub fn argmax(&self) -> &[u32] {
        &self.argmax
    }

    /// `[max_batch, classes]` softmax scores of the last forward, first
    /// `n` rows valid.
    pub fn scores(&self) -> &Tensor {
        &self.scores
    }

    /// Raw logits of the last forward (tests compare these bitwise against
    /// a direct `module_fwd_into` pass).
    pub fn logits(&self) -> &Tensor {
        &self.logits
    }

    /// Build the reply for a request occupying rows `[off, off + n)` of
    /// the last forward. Allocates the reply payload — demux runs outside
    /// the steady-state region.
    pub fn demux(&self, id: u64, off: usize, n: usize) -> Result<ServeReply> {
        if n == 0 || off + n > self.max_batch {
            return Err(Error::Shape(format!(
                "demux of {n} rows at offset {off} overflows max_batch {}",
                self.max_batch
            )));
        }
        let argmax = self
            .argmax
            .get(off..off + n)
            .ok_or_else(|| Error::Shape("argmax shorter than staged rows".into()))?
            .to_vec();
        let flat = self
            .scores
            .data()
            .get(off * self.classes..(off + n) * self.classes)
            .ok_or_else(|| Error::Shape("scores shorter than staged rows".into()))?
            .to_vec();
        Ok(ServeReply {
            id,
            argmax,
            scores: Tensor::from_vec(&[n, self.classes], flat)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::checkpoint::Checkpoint;
    use crate::nn::init::init_params;
    use crate::nn::resmlp_layers;
    use crate::runtime::NativeBackend;
    use crate::util::rng::Pcg32;

    fn engine(max_batch: usize) -> BatchEngine {
        let layers = resmlp_layers(6, 5, 1, 3);
        let mut rng = Pcg32::new(21);
        let groups: Vec<_> = (0..2).map(|_| init_params(&mut rng, &layers)).collect();
        let ck = Checkpoint::new(0, groups, layers.clone());
        let backend = NativeBackend::with_threads(layers, max_batch, 1);
        let predictor = Predictor::from_parts(Box::new(backend), ck).unwrap();
        BatchEngine::new(predictor, max_batch).unwrap()
    }

    fn rand_rows(n: usize, seed: u64) -> Tensor {
        let mut rng = Pcg32::new(seed);
        let mut x = Tensor::zeros(&[n, 6]);
        rng.fill_normal(x.data_mut(), 1.0);
        x
    }

    #[test]
    fn scores_are_softmax_of_logits_and_argmax_wins() {
        let mut e = engine(4);
        let x = rand_rows(3, 1);
        e.stage(0, &x).unwrap();
        e.forward(3).unwrap();
        for row in 0..3 {
            let s = &e.scores().data()[row * 3..(row + 1) * 3];
            let sum: f32 = s.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "row {row} sums to {sum}");
            assert!(s.iter().all(|&v| v > 0.0));
            let l = &e.logits().data()[row * 3..(row + 1) * 3];
            let best = (0..3).max_by(|&a, &b| l[a].total_cmp(&l[b])).unwrap();
            assert_eq!(e.argmax()[row], best as u32);
        }
    }

    #[test]
    fn co_batched_rows_match_solo_rows_bitwise() {
        let mut e = engine(4);
        let a = rand_rows(2, 2);
        let b = rand_rows(1, 3);

        // batch a (2 rows) and b (1 row) together, padding row 4
        e.stage(0, &a).unwrap();
        e.stage(2, &b).unwrap();
        e.forward(3).unwrap();
        let together = e.demux(7, 0, 3).unwrap();

        // now run b alone: identical scores bitwise
        e.stage(0, &b).unwrap();
        e.forward(1).unwrap();
        let solo = e.demux(8, 0, 1).unwrap();
        assert_eq!(solo.scores.data(), &together.scores.data()[2 * 3..3 * 3]);
        assert_eq!(solo.argmax[0], together.argmax[2]);
    }

    #[test]
    fn batcher_is_deterministic_across_interleavings() {
        // the same 4 single-row requests, grouped every possible way, must
        // produce identical per-request replies
        let rows: Vec<Tensor> = (0..4).map(|i| rand_rows(1, 40 + i)).collect();
        let mut reference: Vec<ServeReply> = Vec::new();
        let mut e = engine(4);
        for (i, r) in rows.iter().enumerate() {
            e.stage(0, r).unwrap();
            e.forward(1).unwrap();
            reference.push(e.demux(i as u64, 0, 1).unwrap());
        }
        // every split point of the 4 requests into two consecutive batches
        for split in 1..4 {
            let mut e = engine(4);
            for (batch_lo, batch_hi) in [(0usize, split), (split, 4usize)] {
                for (off, r) in rows[batch_lo..batch_hi].iter().enumerate() {
                    e.stage(off, r).unwrap();
                }
                e.forward(batch_hi - batch_lo).unwrap();
                for i in batch_lo..batch_hi {
                    let got = e.demux(i as u64, i - batch_lo, 1).unwrap();
                    assert_eq!(got.scores, reference[i].scores, "split {split} req {i}");
                    assert_eq!(got.argmax, reference[i].argmax, "split {split} req {i}");
                }
            }
        }
    }

    #[test]
    fn stage_and_forward_reject_overflow() {
        let mut e = engine(2);
        let x = rand_rows(2, 5);
        assert!(e.stage(1, &x).is_err(), "2 rows at offset 1 overflow max_batch 2");
        assert!(e.stage(0, &Tensor::zeros(&[1, 9])).is_err(), "wrong d_in");
        assert!(e.forward(0).is_err());
        assert!(e.forward(3).is_err());
        assert!(e.demux(0, 1, 2).is_err());
    }
}
