//! `sgs serve`: dynamically-batched forward-only inference over the
//! workspace kernels.
//!
//! # Architecture
//!
//! ```text
//!   Transport front (Frame::Predict)  ─┐
//!                                      ├─> mpsc queue ─> engine loop ─> replies
//!   HTTP front (POST /predict)        ─┘                 (one thread,
//!                                                         one BatchEngine)
//! ```
//!
//! Both fronts translate their wire format into a [`ServeRequest`] and
//! block on a per-request reply channel. A single engine thread drains
//! the queue into one padded forward pass: it stages rows until either
//! [`ServeConfig::max_batch`] rows are waiting or
//! [`ServeConfig::max_wait_ms`] has passed since the batch opened, runs
//! [`BatchEngine::forward`] ONCE over the full workspace, then demuxes
//! per-request argmax + softmax scores. Because every kernel is per-row
//! with a fixed accumulation order, co-batching never changes any
//! request's bits — `tests/serve_e2e.rs` pins replies against a direct
//! [`crate::runtime::ComputeBackend::module_fwd_into`] pass.
//!
//! # Protocol (Transport front)
//!
//! The same handshake discipline as the dist runtime: the client opens
//! with [`Frame::Hello`] carrying [`WIRE_VERSION`] and its codec id; the
//! server echoes the hello iff the version matches and the codec equals
//! [`ServeConfig::codec`] (otherwise [`Frame::Abort`] names what it
//! expected), and both sides switch codecs. After that the connection is
//! a synchronous request/reply loop of [`Frame::Predict`] /
//! [`Frame::Prediction`]; concurrency comes from opening more
//! connections, not from pipelining. [`Frame::Shutdown`] closes the
//! connection; a per-request failure is reported as [`Frame::Abort`] and
//! also closes it. [`ServeClient`] wraps the client side of all of this.
//!
//! # Shutdown
//!
//! The runtime shares the worker CLI's process-wide shutdown flag
//! (`crate::net::worker`): SIGTERM/SIGINT (via
//! `install_signal_handlers`) or `request_shutdown()` stops the accept
//! loops and the engine loop, and [`run`] returns with the final
//! [`ServeStats`].

pub mod batcher;
pub mod http;

use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use crate::config::ServeConfig;
use crate::error::{Error, Result};
use crate::net::wire::{Frame, WireCodec, WIRE_VERSION};
use crate::net::worker::shutdown_flag;
use crate::net::{TcpTransport, Transport};
use crate::obs::{Deadline, MetricsRegistry, Phase, Span, Tracer, WallClock, NO_COORD};
use crate::session::Predictor;
use crate::tensor::Tensor;

pub use batcher::{BatchEngine, ServeReply, ServeRequest};

/// Poll granularity of the engine loop's idle wait and the accept loops.
const IDLE_POLL: Duration = Duration::from_millis(50);
/// Spin-sleep while topping up an open batch.
const TOPUP_POLL: Duration = Duration::from_micros(100);
/// Client-side reply deadlines.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);
const REPLY_TIMEOUT: Duration = Duration::from_secs(60);

/// Everything `sgs serve` needs to start: where the weights are, where
/// to listen, and the batching knobs.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    pub cfg: ServeConfig,
    /// checkpoint base path (`<base>.json` + `<base>.bin`, from
    /// `sgs train --ckpt-out`)
    pub ckpt: PathBuf,
    /// Transport front address (`host:port`, port 0 for ephemeral);
    /// `None` disables the front
    pub listen: Option<String>,
    /// HTTP front address; `None` disables the front
    pub http: Option<String>,
}

/// What the runtime did between start and shutdown.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// requests answered successfully
    pub requests: u64,
    /// batched forward passes executed
    pub batches: u64,
    /// total rows forwarded on behalf of requests (excludes padding)
    pub rows: u64,
}

/// One staged request's slice of the current batch.
struct PendingSlot {
    id: u64,
    off: usize,
    n: usize,
    reply: Sender<Result<ServeReply>>,
    enqueued_us: u64,
}

/// Load the checkpoint, bind the configured fronts, and serve until the
/// process-wide shutdown flag is raised. Announces each bound address on
/// stdout (`sgs serve listening on ADDR` / `sgs serve http on ADDR`) so
/// launchers and CI can parse the ephemeral ports.
pub fn run(
    opts: &ServeOptions,
    metrics: &Arc<MetricsRegistry>,
    tracer: Option<&Arc<Tracer>>,
) -> Result<ServeStats> {
    opts.cfg.validate()?;
    if opts.listen.is_none() && opts.http.is_none() {
        return Err(Error::Config(
            "serve needs at least one front: --listen and/or --http".into(),
        ));
    }
    let predictor =
        Predictor::from_checkpoint(&opts.ckpt, opts.cfg.max_batch, opts.cfg.compute_threads)?;
    let engine = BatchEngine::new(predictor, opts.cfg.max_batch)?;
    let bind = |addr: &String| -> Result<TcpListener> {
        TcpListener::bind(addr).map_err(|e| Error::Net(format!("bind {addr}: {e}")))
    };
    let transport = opts.listen.as_ref().map(bind).transpose()?;
    let http = opts.http.as_ref().map(bind).transpose()?;
    run_with_listeners(engine, &opts.cfg, transport, http, metrics, tracer)
}

/// [`run`] with pre-bound listeners — the e2e tests bind on
/// `127.0.0.1:0` themselves so they know the ports before starting the
/// runtime on a background thread.
pub fn run_with_listeners(
    mut engine: BatchEngine,
    cfg: &ServeConfig,
    transport: Option<TcpListener>,
    http: Option<TcpListener>,
    metrics: &Arc<MetricsRegistry>,
    tracer: Option<&Arc<Tracer>>,
) -> Result<ServeStats> {
    let clock = Arc::new(WallClock::new());
    let (tx, rx) = mpsc::channel::<ServeRequest>();
    let mut accepters = Vec::new();

    if let Some(listener) = transport {
        let local = listener
            .local_addr()
            .map_err(|e| Error::Net(format!("local_addr: {e}")))?;
        println!("sgs serve listening on {local}");
        use_stdout_now()?;
        let codec = cfg.codec;
        let front_tx = tx.clone();
        let front_clock = Arc::clone(&clock);
        accepters.push(
            std::thread::Builder::new()
                .name("serve-accept-transport".into())
                .spawn(move || accept_transport(listener, codec, front_tx, front_clock))
                .map_err(|e| Error::Net(format!("spawn accept thread: {e}")))?,
        );
    }
    if let Some(listener) = http {
        let local = listener
            .local_addr()
            .map_err(|e| Error::Net(format!("local_addr: {e}")))?;
        println!("sgs serve http on {local}");
        use_stdout_now()?;
        let front_tx = tx.clone();
        let front_clock = Arc::clone(&clock);
        let front_metrics = Arc::clone(metrics);
        accepters.push(
            std::thread::Builder::new()
                .name("serve-accept-http".into())
                .spawn(move || http::accept_http(listener, front_tx, front_clock, front_metrics))
                .map_err(|e| Error::Net(format!("spawn accept thread: {e}")))?,
        );
    }
    drop(tx);

    let stats = engine_loop(&mut engine, cfg, rx, metrics, tracer, &clock);
    for handle in accepters {
        if handle.join().is_err() {
            return Err(Error::Net("serve accept thread panicked".into()));
        }
    }
    stats
}

/// Flush stdout so launchers blocking on the announce line see it
/// immediately (same idiom as the dist worker's `serve_addr`).
fn use_stdout_now() -> Result<()> {
    use std::io::Write;
    std::io::stdout()
        .flush()
        .map_err(|e| Error::Net(format!("flush stdout: {e}")))
}

/// The batching core: drain the queue into padded forward passes until
/// shutdown. Metric handles are registered up front; per-batch work after
/// warmup touches only preallocated storage (plus the reply payloads,
/// which are per-request and outside the `#[steady_state]` region).
fn engine_loop(
    engine: &mut BatchEngine,
    cfg: &ServeConfig,
    rx: Receiver<ServeRequest>,
    metrics: &Arc<MetricsRegistry>,
    tracer: Option<&Arc<Tracer>>,
    clock: &WallClock,
) -> Result<ServeStats> {
    let latency_us = metrics.histogram(
        "serve_latency_us",
        &[
            100.0, 250.0, 500.0, 1_000.0, 2_500.0, 5_000.0, 10_000.0, 25_000.0, 50_000.0,
            100_000.0, 250_000.0, 1_000_000.0,
        ],
    );
    let row_bounds: Vec<f64> = (1..=engine.max_batch()).map(|i| i as f64).collect();
    let batch_rows = metrics.histogram("serve_batch_rows", &row_bounds);
    let requests_total = metrics.counter("serve_requests_total");
    let errors_total = metrics.counter("serve_errors_total");
    let batches_total = metrics.counter("serve_batches_total");
    let qps = metrics.gauge("serve_qps");

    let flag = shutdown_flag();
    let max_batch = engine.max_batch();
    let max_wait = Duration::from_millis(cfg.max_wait_ms);
    let mut staged: Vec<PendingSlot> = Vec::with_capacity(max_batch);
    let mut carry: Option<ServeRequest> = None;
    let mut stats = ServeStats::default();

    while !flag.load(Ordering::SeqCst) {
        // open a batch with the carried-over or next queued request
        let first = match carry.take() {
            Some(req) => req,
            None => match rx.recv_timeout(IDLE_POLL) {
                Ok(req) => req,
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => break,
            },
        };
        staged.clear();
        let mut rows = 0usize;
        stage_one(engine, &mut staged, &mut rows, first, &errors_total);

        // top up until the batch is full or the wait budget is spent
        let wait = Deadline::after(max_wait);
        while rows < max_batch && !wait.expired() && !flag.load(Ordering::SeqCst) {
            match rx.try_recv() {
                Ok(req) => {
                    let n = match req.x.shape() {
                        s if s.len() == 2 => s[0],
                        _ => 0,
                    };
                    if (1..=max_batch).contains(&n) && rows + n > max_batch {
                        // doesn't fit this batch — it opens the next one
                        carry = Some(req);
                        break;
                    }
                    stage_one(engine, &mut staged, &mut rows, req, &errors_total);
                }
                Err(TryRecvError::Empty) => std::thread::sleep(TOPUP_POLL),
                Err(TryRecvError::Disconnected) => break,
            }
        }
        if rows == 0 {
            continue;
        }

        let start_us = clock.now_us();
        if let Err(e) = engine.forward(rows) {
            errors_total.add(staged.len() as u64);
            for slot in staged.drain(..) {
                let _ = slot.reply.send(Err(Error::other(format!("serve forward: {e}"))));
            }
            continue;
        }
        let dur_us = clock.now_us().saturating_sub(start_us);
        if let Some(tr) = tracer {
            tr.record(Span {
                track: 0,
                phase: Phase::Serve,
                s: NO_COORD,
                k: NO_COORD,
                t: stats.batches as i64,
                start_us,
                dur_us,
            });
        }
        let done_us = clock.now_us();
        for slot in staged.drain(..) {
            let reply = engine.demux(slot.id, slot.off, slot.n);
            latency_us.observe(done_us.saturating_sub(slot.enqueued_us) as f64);
            requests_total.inc();
            stats.requests += 1;
            stats.rows += slot.n as u64;
            let _ = slot.reply.send(reply);
        }
        stats.batches += 1;
        batches_total.inc();
        batch_rows.observe(rows as f64);
        qps.set(stats.requests as f64 / clock.elapsed_s().max(1.0e-9));
    }
    Ok(stats)
}

/// Stage one request into the open batch, replying with the error
/// immediately if its rows don't fit the model (the batch proceeds
/// without it).
fn stage_one(
    engine: &mut BatchEngine,
    staged: &mut Vec<PendingSlot>,
    rows: &mut usize,
    req: ServeRequest,
    errors_total: &crate::obs::Counter,
) {
    match engine.stage(*rows, &req.x) {
        Ok(n) => {
            staged.push(PendingSlot {
                id: req.id,
                off: *rows,
                n,
                reply: req.reply,
                enqueued_us: req.enqueued_us,
            });
            *rows += n;
        }
        Err(e) => {
            errors_total.inc();
            let _ = req.reply.send(Err(e));
        }
    }
}

/// Enqueue a request and block for its reply — the shared path of both
/// fronts.
pub(crate) fn enqueue_and_wait(
    tx: &Sender<ServeRequest>,
    clock: &WallClock,
    id: u64,
    x: Tensor,
) -> Result<ServeReply> {
    let (reply_tx, reply_rx) = mpsc::channel();
    tx.send(ServeRequest {
        id,
        x,
        reply: reply_tx,
        enqueued_us: clock.now_us(),
    })
    .map_err(|_| Error::Net("serve queue closed (server shutting down)".into()))?;
    match reply_rx.recv() {
        Ok(result) => result,
        Err(_) => Err(Error::Net("serve engine dropped the request".into())),
    }
}

/// Accept Transport connections until shutdown; each connection gets a
/// detached handler thread running the synchronous predict loop.
fn accept_transport(
    listener: TcpListener,
    codec: WireCodec,
    tx: Sender<ServeRequest>,
    clock: Arc<WallClock>,
) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    let flag = shutdown_flag();
    while !flag.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                if stream.set_nonblocking(false).is_err() {
                    continue;
                }
                let conn_tx = tx.clone();
                let conn_clock = Arc::clone(&clock);
                let spawned = std::thread::Builder::new()
                    .name("serve-conn".into())
                    .spawn(move || {
                        if let Ok(mut t) = TcpTransport::new(stream) {
                            t.interrupt_on(shutdown_flag());
                            let _ = serve_conn(&mut t, codec, &conn_tx, &conn_clock);
                            t.close();
                        }
                    });
                if spawned.is_err() {
                    // out of threads: drop the connection, keep accepting
                    continue;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(IDLE_POLL);
            }
            Err(_) => std::thread::sleep(IDLE_POLL),
        }
    }
}

/// One Transport connection: codec handshake, then a synchronous
/// `Predict` → `Prediction` loop until the client closes, sends
/// `Shutdown`, or a request fails (reported as `Abort`).
fn serve_conn(
    t: &mut TcpTransport,
    expected: WireCodec,
    tx: &Sender<ServeRequest>,
    clock: &WallClock,
) -> Result<()> {
    let (frame, _) = t.recv()?;
    match frame {
        Frame::Hello { version, codec } if version == WIRE_VERSION as u32 => {
            if codec != expected.id() {
                let msg = format!(
                    "codec mismatch: client offered id {codec}, server speaks {}",
                    expected.name()
                );
                t.send(&Frame::Abort { msg: msg.clone() }).ok();
                return Err(Error::Net(msg));
            }
            t.send(&Frame::Hello {
                version: WIRE_VERSION as u32,
                codec,
            })?;
            t.set_codec(expected);
        }
        Frame::Hello { version, .. } => {
            let msg = format!(
                "wire version mismatch: client sent v{version}, this build speaks v{WIRE_VERSION}"
            );
            t.send(&Frame::Abort { msg: msg.clone() }).ok();
            return Err(Error::Net(msg));
        }
        other => {
            let msg = format!("expected hello, got {} frame", other.name());
            t.send(&Frame::Abort { msg: msg.clone() }).ok();
            return Err(Error::Net(msg));
        }
    }
    loop {
        let (frame, _) = match t.recv() {
            Ok(out) => out,
            // client hung up, or the shutdown flag interrupted the poll
            Err(_) => return Ok(()),
        };
        match frame {
            Frame::Predict { id, x } => match enqueue_and_wait(tx, clock, id, x) {
                Ok(rep) => {
                    t.send(&Frame::Prediction {
                        id: rep.id,
                        argmax: rep.argmax,
                        scores: rep.scores,
                    })?;
                }
                Err(e) => {
                    t.send(&Frame::Abort { msg: format!("{e}") }).ok();
                    return Ok(());
                }
            },
            Frame::Shutdown => return Ok(()),
            other => {
                let msg = format!("expected predict, got {} frame", other.name());
                t.send(&Frame::Abort { msg: msg.clone() }).ok();
                return Err(Error::Net(msg));
            }
        }
    }
}

/// Client side of the Transport front: handshake on connect, then
/// synchronous [`ServeClient::predict`] calls. Used by `sgs predict` and
/// the QPS bench.
pub struct ServeClient {
    t: TcpTransport,
    next_id: u64,
}

impl ServeClient {
    /// Connect and negotiate `codec` (must equal the server's
    /// `ServeConfig::codec`).
    pub fn connect(addr: &str, codec: WireCodec) -> Result<ServeClient> {
        let mut t = TcpTransport::connect(addr)?;
        t.send(&Frame::Hello {
            version: WIRE_VERSION as u32,
            codec: codec.id(),
        })?;
        match t.recv_deadline(HANDSHAKE_TIMEOUT)? {
            (Frame::Hello { version, codec: c }, _)
                if version == WIRE_VERSION as u32 && c == codec.id() =>
            {
                t.set_codec(codec);
                Ok(ServeClient { t, next_id: 0 })
            }
            (Frame::Abort { msg }, _) => {
                Err(Error::Net(format!("server rejected handshake: {msg}")))
            }
            (other, _) => Err(Error::Net(format!(
                "unexpected {} frame in handshake",
                other.name()
            ))),
        }
    }

    /// Send one `[n, d_in]` batch and block for its scores.
    pub fn predict(&mut self, x: &Tensor) -> Result<ServeReply> {
        let id = self.next_id;
        self.next_id = self.next_id.wrapping_add(1);
        self.t.send(&Frame::Predict { id, x: x.clone() })?;
        match self.t.recv_deadline(REPLY_TIMEOUT)? {
            (Frame::Prediction { id: rid, argmax, scores }, _) if rid == id => Ok(ServeReply {
                id: rid,
                argmax,
                scores,
            }),
            (Frame::Abort { msg }, _) => Err(Error::Net(format!("server aborted: {msg}"))),
            (other, _) => Err(Error::Net(format!(
                "unexpected {} frame in reply",
                other.name()
            ))),
        }
    }

    /// Politely end the connection (best-effort `Shutdown` frame).
    pub fn close(&mut self) {
        self.t.send(&Frame::Shutdown).ok();
        self.t.close();
    }
}
