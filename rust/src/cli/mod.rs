//! Command-line launcher: argument parsing + command handlers.

pub mod args;
pub mod commands;

pub use args::Args;
pub use commands::{dispatch, USAGE};
