//! CLI commands: the launcher surface of the framework.
//!
//! * `train`     — run one (S,K) experiment, write CSV
//! * `compare`   — run the paper's four Section-5 methods side by side
//! * `worker`    — host module agents for a remote coordinator (TCP)
//! * `launch`    — coordinator: spawn/dial workers, run distributed
//! * `describe`  — grid/topology/spectral report for a config
//! * `trace`     — print the Fig. 1 pipeline schedule
//! * `trace-report` — analyze a `--trace-out` Chrome trace JSON
//! * `calibrate` — measure the cost model and print the timing table
//! * `serve`     — dynamically-batched inference over a checkpoint
//! * `predict`   — query a running `serve` over the Transport front

use std::io::{BufRead as _, Write as _};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use crate::cli::args::Args;
use crate::config::{ExperimentConfig, ModelShape, ModelSpec, Placement, StackModel};
use crate::coordinator::{build_dataset, AgentGrid};
use crate::error::{Error, Result};
use crate::graph::Topology;
use crate::monitor::{Monitor, MonitorOptions, RunInfo};
use crate::net::{TcpTransport, Transport};
use crate::nn::resolve_threads;
use crate::obs::{MetricsRegistry, Tracer, WallClock, DEFAULT_SPAN_CAPACITY};
use crate::runtime::{make_backend, BackendKind, ComputeBackend};
use crate::session::{EngineKind, EventWriter, Session};
use crate::simclock::{method_iter_s, CostModel};
use crate::staleness::Schedule;
use crate::trainer::LrSchedule;

pub const USAGE: &str = "\
sgs — Distributed Deep Learning using Stochastic Gradient Staleness

USAGE: sgs <command> [--flag value]...

COMMANDS
  train      run one experiment            (--s --k --iters --lr --topology
             --alpha --batch --seed --backend native|xla --artifacts DIR
             --engine sim|threaded|dist --model tiny|small|paper|cnn
             --opt sgd|momentum:B|nesterov:B --mode fd|dbp
             --compensate none|dc:LAMBDA|accum:N
             --workers N (dist engine: in-process workers)
             --codec raw|f16|delta (dist data-plane wire codec)
             --compute-threads N (0 = all cores; any N is bit-identical)
             --out CSV --events-out JSONL --trace-out JSON --clock
             --ckpt-out BASE: save final weights as BASE.json + BASE.bin
             --status-addr HOST:PORT: live status server (GET /metrics
             Prometheus text, /status JSON, /healthz 200|503)
             --telemetry-out JSONL --telemetry-period-ms MS (default 500)
             --stall-timeout-s S: /healthz stall deadline (default 60))
  compare    run the paper's four methods  (same flags; --out-dir DIR)
  worker     host agents for a coordinator (--listen HOST:PORT, port 0 = any;
             announces the bound address on stdout; exits on coordinator
             shutdown, connection loss, or SIGTERM/ctrl-c)
  launch     run distributed across processes (train flags plus
             --workers N: spawn N loopback workers, or
             --hosts A:P,B:P,...: dial already-running `sgs worker`s;
             --codec raw|f16|delta: compress the p2p data plane;
             placement from the config or an even split;
             --status-addr/--telemetry-out/--stall-timeout-s as in train,
             with per-worker liveness folded into /status and /healthz)
  top        live dashboard over a status server (--connect HOST:PORT
             from train/launch --status-addr or serve --http;
             --once: print one frame and exit;
             --interval-ms MS: poll cadence, default 1000)
  describe   print grid + spectral report  (--s --k --topology --alpha)
  trace      print the Fig. 1 schedule     (--k --iters)
  trace-report  analyze a trace            (sgs trace-report FILE [--json];
             per-module/per-phase breakdown, pipeline fill vs steady state,
             stragglers — FILE comes from train/launch --trace-out)
  calibrate  cost model + timing table     (--backend --artifacts --model
             --compute-threads N)
  serve      batched inference over a checkpoint (--ckpt BASE from
             train --ckpt-out; --listen HOST:PORT wire-protocol front,
             default 127.0.0.1:0; --http HOST:PORT HTTP/1.1 front
             (POST /predict, GET /metrics, GET /healthz);
             --max-batch N --max-wait-ms MS --codec raw|f16|delta
             --compute-threads N --trace-out JSON; SIGTERM/ctrl-c stops)
  predict    query a running serve         (--connect HOST:PORT;
             --x F,F,... one row, or --json FILE with {\"x\": [[...]]};
             --codec raw|f16|delta --repeat N)
  help       this text
";

fn model_of(name: &str) -> Result<ModelSpec> {
    match name.trim().to_ascii_lowercase().as_str() {
        "tiny" => Ok(ModelShape::tiny().into()),
        "small" => Ok(ModelShape::small().into()),
        "paper" => Ok(ModelShape::paper().into()),
        "cnn" => Ok(StackModel::cifar_cnn().into()),
        _ => Err(crate::error::Error::Cli(format!(
            "unknown model {name:?} (want tiny|small|paper|cnn)"
        ))),
    }
}

/// Assemble an ExperimentConfig from flags (shared by train/compare).
fn config_from_args(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = ExperimentConfig::default();
    if let Some(path) = args.get("config") {
        cfg = ExperimentConfig::load(Path::new(path))?;
    }
    cfg.s = args.get_usize("s", cfg.s)?;
    cfg.k = args.get_usize("k", cfg.k)?;
    cfg.iters = args.get_usize("iters", cfg.iters)?;
    cfg.batch = args.get_usize("batch", cfg.batch)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    cfg.dataset_n = args.get_usize("dataset-n", cfg.dataset_n)?;
    cfg.delta_every = args.get_usize("delta-every", cfg.delta_every)?;
    cfg.gossip_rounds = args.get_usize("gossip-rounds", cfg.gossip_rounds)?;
    cfg.eval_every = args.get_usize("eval-every", cfg.eval_every)?;
    cfg.compute_threads = args.get_usize("compute-threads", cfg.compute_threads)?;
    // only override the config file's model when the flag is present (the
    // default config already carries the `small` geometry)
    if let Some(m) = args.get("model") {
        cfg.model = model_of(m)?;
    }
    cfg.topology = Topology::parse(args.get_or("topology", &cfg.topology.name()))?;
    if let Some(a) = args.get("alpha") {
        cfg.alpha = Some(a.parse().map_err(|_| {
            crate::error::Error::Cli(format!("--alpha wants a number, got {a:?}"))
        })?);
    }
    if let Some(lr) = args.get("lr") {
        cfg.lr = LrSchedule::parse(lr)?;
    }
    if let Some(opt) = args.get("opt") {
        cfg.optimizer = crate::trainer::OptimizerKind::parse(opt)?;
    }
    if let Some(comp) = args.get("compensate") {
        cfg.compensate = crate::compensate::CompensatorKind::parse(comp)?;
    }
    if let Some(mode) = args.get("mode") {
        cfg.mode = crate::staleness::PipelineMode::parse(mode)?;
    }
    if let Some(codec) = args.get("codec") {
        cfg.codec = crate::net::WireCodec::parse(codec)?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn backend_flags(args: &Args) -> Result<(BackendKind, PathBuf)> {
    let kind = BackendKind::parse(args.get_or("backend", "native"))?;
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    Ok((kind, artifacts))
}

/// Apply the `--workers` flag to a dist-engine config: synthesize the even
/// placement when the config has none, reject a mismatch when it has one.
fn apply_workers_flag(
    cfg: &mut ExperimentConfig,
    engine: EngineKind,
    workers: usize,
) -> Result<()> {
    if workers == 0 {
        return Ok(());
    }
    if engine != EngineKind::Dist {
        return Err(Error::Cli("--workers requires --engine dist".into()));
    }
    match &cfg.placement {
        None => {
            cfg.placement = Some(Placement::even(workers, cfg.s, cfg.k)?);
            Ok(())
        }
        Some(p) if p.workers == workers => Ok(()),
        Some(p) => Err(Error::Cli(format!(
            "--workers {workers} conflicts with the config placement ({} workers)",
            p.workers
        ))),
    }
}

/// Drive a built session to completion: stream events to the optional
/// JSONL sink (feeding the optional monitor's watchdog per event),
/// export the optional trace, then print the summary and write the
/// optional CSV (shared by `train` and `launch`). On a run error the
/// monitor latches `Stalled` and keeps `/healthz` at 503 for its linger
/// window before the error propagates, so external probes observe the
/// failure before process exit.
fn stream_and_report(
    mut session: Session,
    monitor: Option<Monitor>,
    out_csv: Option<PathBuf>,
    events_out: Option<PathBuf>,
    trace_out: Option<PathBuf>,
    ckpt_out: Option<PathBuf>,
) -> Result<()> {
    let mut events = match &events_out {
        Some(path) => Some(EventWriter::create(path)?),
        None => None,
    };
    let wall = WallClock::new();
    let run = session.run_streaming(|ev| {
        if let Some(m) = &monitor {
            m.note_step(ev.t as u64 + 1);
        }
        if let Some(w) = events.as_mut() {
            w.write(ev)?;
        }
        Ok(())
    });
    if let Err(e) = run {
        if let Some(m) = &monitor {
            m.fail(&e.to_string());
        }
        return Err(e);
    }
    if let Some(w) = events.as_mut() {
        w.flush()?;
    }
    if let Some(path) = &trace_out {
        session.write_trace(path, wall.elapsed_s())?;
        println!("wrote trace {}", path.display());
    }
    if let Some(base) = &ckpt_out {
        session.checkpoint()?.save(base)?;
        println!("wrote checkpoint {}.json + {}.bin", base.display(), base.display());
    }
    let out = session.finish();

    let s = out.recorder.summary();
    println!(
        "done: final train loss {:?}, eval loss {:?}, acc {:?}, delta {:.3e}, gamma {:.4}",
        s.final_train_loss, s.final_eval_loss, s.final_eval_acc, out.final_delta, out.gamma
    );
    if let Some(path) = out_csv {
        out.recorder.write_csv(&path)?;
        println!("wrote {}", path.display());
    }
    if let Some(path) = events_out {
        println!("wrote events {}", path.display());
    }
    if let Some(m) = monitor {
        m.shutdown();
    }
    Ok(())
}

/// Parse the monitor flags shared by `train` and `launch`; `None` when
/// neither `--status-addr` nor `--telemetry-out` was given.
fn monitor_flags(args: &Args) -> Result<Option<MonitorOptions>> {
    let status_addr = args.get("status-addr").map(String::from);
    let telemetry_out = args.get("telemetry-out").map(PathBuf::from);
    let period_ms = args.get_u64("telemetry-period-ms", 500)?;
    let stall_timeout_s = args.get_f64("stall-timeout-s", 60.0)?;
    if status_addr.is_none() && telemetry_out.is_none() {
        return Ok(None);
    }
    let mut opts = MonitorOptions::new("");
    opts.status_addr = status_addr;
    opts.telemetry_out = telemetry_out;
    opts.sample_period = Duration::from_millis(period_ms.max(1));
    opts.health.stall_timeout_s = stall_timeout_s;
    Ok(Some(opts))
}

/// Start the monitor for a built session (train/launch with
/// `--status-addr`/`--telemetry-out`).
fn start_monitor(
    opts: MonitorOptions,
    engine: &str,
    session: &Session,
    workers: usize,
    metrics: &Arc<MetricsRegistry>,
    tracer: Option<&Arc<Tracer>>,
) -> Result<Monitor> {
    let info = RunInfo {
        engine: engine.to_string(),
        s: session.cfg().s,
        k: session.cfg().k,
        workers,
    };
    let monitor = Monitor::start(opts, info, Arc::clone(metrics), tracer.cloned())?;
    if let Some(addr) = monitor.addr() {
        println!("status server listening on {addr}");
    }
    Ok(monitor)
}

pub fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = config_from_args(args)?;
    let (kind, artifacts) = backend_flags(args)?;
    let engine = EngineKind::parse(args.get_or("engine", "sim"))?;
    let workers = args.get_usize("workers", 0)?;
    let out_csv = args.get("out").map(PathBuf::from);
    let events_out = args.get("events-out").map(PathBuf::from);
    let trace_out = args.get("trace-out").map(PathBuf::from);
    let ckpt_out = args.get("ckpt-out").map(PathBuf::from);
    let clock = args.get_bool("clock");
    let monitor_opts = monitor_flags(args)?;
    args.finish()?;
    apply_workers_flag(&mut cfg, engine, workers)?;

    println!(
        "train: {} S={} K={} topology={} backend={} engine={} iters={}",
        cfg.name,
        cfg.s,
        cfg.k,
        cfg.topology.name(),
        kind.as_str(),
        engine.as_str(),
        cfg.iters
    );
    let dist_workers = cfg.placement.as_ref().map(|p| p.workers).unwrap_or(0);
    let metrics = Arc::new(MetricsRegistry::new());
    let mut builder = Session::builder(cfg)
        .backend(kind)
        .artifacts(artifacts)
        .engine(engine)
        .calibrate_clock(clock)
        .metrics(Arc::clone(&metrics));
    // the status server folds occupancy out of the tracer, so a monitor
    // implies one even without --trace-out (attach is a pure observer)
    let tracer = (trace_out.is_some() || monitor_opts.is_some())
        .then(|| Arc::new(Tracer::new(DEFAULT_SPAN_CAPACITY)));
    if let Some(t) = &tracer {
        builder = builder.tracer(Arc::clone(t));
    }
    let session = builder.build()?;
    let monitor = match monitor_opts {
        Some(opts) => Some(start_monitor(
            opts,
            engine.as_str(),
            &session,
            dist_workers,
            &metrics,
            tracer.as_ref(),
        )?),
        None => None,
    };
    stream_and_report(session, monitor, out_csv, events_out, trace_out, ckpt_out)
}

/// `sgs worker --listen HOST:PORT`: host module agents for a remote
/// coordinator. Announces the bound address on stdout (port 0 picks a free
/// one), serves one coordinator session, exits 0 on clean shutdown.
pub fn cmd_worker(args: &Args) -> Result<()> {
    let listen = args.get_or("listen", "127.0.0.1:0").to_string();
    args.finish()?;
    crate::net::worker::install_signal_handlers();
    crate::net::worker::serve_addr(&listen)
}

/// `sgs launch`: run one experiment as coordinator + worker processes —
/// `--workers N` spawns N loopback `sgs worker` children, `--hosts` dials
/// already-running workers on other machines.
pub fn cmd_launch(args: &Args) -> Result<()> {
    let mut cfg = config_from_args(args)?;
    let (kind, artifacts) = backend_flags(args)?;
    let out_csv = args.get("out").map(PathBuf::from);
    let events_out = args.get("events-out").map(PathBuf::from);
    let trace_out = args.get("trace-out").map(PathBuf::from);
    let ckpt_out = args.get("ckpt-out").map(PathBuf::from);
    let clock = args.get_bool("clock");
    let hosts: Option<Vec<String>> = args.get("hosts").map(|h| {
        h.split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(String::from)
            .collect()
    });
    let workers_flag = args.get_usize("workers", 0)?;
    let monitor_opts = monitor_flags(args)?;
    args.finish()?;

    let n_workers = match (&hosts, workers_flag) {
        (Some(h), 0) => h.len(),
        (Some(h), n) if n == h.len() => n,
        (Some(h), n) => {
            return Err(Error::Cli(format!(
                "--workers {n} conflicts with {} --hosts entries",
                h.len()
            )))
        }
        (None, 0) => cfg
            .placement
            .as_ref()
            .map(|p| p.workers)
            .ok_or_else(|| {
                Error::Cli(
                    "launch needs --workers N, --hosts LIST, or a config placement".into(),
                )
            })?,
        (None, n) => n,
    };
    if cfg.placement.is_none() {
        cfg.placement = Some(Placement::even(n_workers, cfg.s, cfg.k)?);
    }
    let placement = cfg.placement.clone().expect("just ensured");
    if placement.workers != n_workers {
        return Err(Error::Cli(format!(
            "config placement wants {} workers, launch resolved {n_workers}",
            placement.workers
        )));
    }

    // connect the fleet: dial --hosts, or spawn loopback children that
    // announce their ephemeral port on stdout
    let mut children: Vec<std::process::Child> = Vec::new();
    let connect_result: Result<Vec<Box<dyn Transport>>> = match &hosts {
        Some(hs) => hs
            .iter()
            .map(|h| {
                TcpTransport::connect(h.as_str()).map(|t| Box::new(t) as Box<dyn Transport>)
            })
            .collect(),
        None => (0..n_workers)
            .map(|i| {
                let exe = std::env::current_exe()?;
                let mut child = std::process::Command::new(&exe)
                    .args(["worker", "--listen", "127.0.0.1:0"])
                    .stdout(std::process::Stdio::piped())
                    .spawn()?;
                let stdout = child.stdout.take().expect("stdout was piped");
                children.push(child);
                let mut line = String::new();
                std::io::BufReader::new(stdout).read_line(&mut line)?;
                let addr = line
                    .rsplit(' ')
                    .next()
                    .map(str::trim)
                    .filter(|a| !a.is_empty())
                    .ok_or_else(|| {
                        Error::Net(format!("worker {i} announced no address: {line:?}"))
                    })?
                    .to_string();
                eprintln!("launch: worker {i} listening on {addr}");
                Ok(Box::new(TcpTransport::connect(addr.as_str())?) as Box<dyn Transport>)
            })
            .collect(),
    };

    let run = connect_result.and_then(|transports| {
        println!(
            "launch: {} S={} K={} workers={} backend={} engine=dist iters={}",
            cfg.name,
            cfg.s,
            cfg.k,
            n_workers,
            kind.as_str(),
            cfg.iters
        );
        let metrics = Arc::new(MetricsRegistry::new());
        let mut builder = Session::builder(cfg)
            .backend(kind)
            .artifacts(artifacts)
            .engine(EngineKind::Dist)
            .dist_workers(transports)
            .calibrate_clock(clock)
            .metrics(Arc::clone(&metrics));
        let tracer = (trace_out.is_some() || monitor_opts.is_some())
            .then(|| Arc::new(Tracer::new(DEFAULT_SPAN_CAPACITY)));
        if let Some(t) = &tracer {
            builder = builder.tracer(Arc::clone(t));
        }
        let session = builder.build()?;
        let monitor = match monitor_opts {
            Some(opts) => Some(start_monitor(
                opts,
                "dist",
                &session,
                n_workers,
                &metrics,
                tracer.as_ref(),
            )?),
            None => None,
        };
        stream_and_report(session, monitor, out_csv, events_out, trace_out, ckpt_out)
    });

    // the engine's teardown asked the workers to exit; reap them (kill
    // first on the error path so nothing lingers)
    for mut child in children {
        if run.is_err() {
            let _ = child.kill();
        }
        let _ = child.wait();
    }
    run
}

pub fn cmd_compare(args: &Args) -> Result<()> {
    let base = config_from_args(args)?;
    let (kind, artifacts) = backend_flags(args)?;
    let engine = EngineKind::parse(args.get_or("engine", "sim"))?;
    let out_dir = PathBuf::from(args.get_or("out-dir", "bench_out"));
    args.finish()?;

    let ds = Arc::new(build_dataset(&base));
    // one backend serves every method; give its kernels the per-group
    // share of the worker budget (same split Session::build applies) so
    // the S=4 methods' group fan-out doesn't multiply with kernel fan-out
    let resolved = resolve_threads(base.compute_threads);
    let kernel_threads = (resolved / resolved.min(base.s.max(1))).max(1);
    let backend: Arc<dyn ComputeBackend> =
        Arc::from(make_backend(
            kind,
            &artifacts,
            base.model.layers(),
            base.batch,
            kernel_threads,
        )?);
    let cm = CostModel::calibrate(backend.as_ref(), 3);

    println!(
        "{:<16} {:>6} {:>6} {:>12} {:>12} {:>12} {:>10}",
        "method", "S", "K", "iter_ms", "final_loss", "eval_loss", "delta"
    );
    for (label, cfg) in ExperimentConfig::paper_methods(&base) {
        let out = Session::builder(cfg.clone())
            .with_backend(backend.clone())
            .dataset(ds.clone())
            .engine(engine)
            .cost_model(&cm)
            .build()?
            .run_to_end()?;
        let s = out.recorder.summary();
        println!(
            "{:<16} {:>6} {:>6} {:>12.3} {:>12.4} {:>12.4} {:>10.2e}",
            label,
            cfg.s,
            cfg.k,
            out.iter_time_s * 1e3,
            s.final_train_loss.unwrap_or(f64::NAN),
            s.final_eval_loss.unwrap_or(f64::NAN),
            out.final_delta,
        );
        std::fs::create_dir_all(&out_dir)?;
        out.recorder
            .write_csv(out_dir.join(format!("compare_{label}.csv")))?;
    }
    println!("CSVs in {}", out_dir.display());
    Ok(())
}

pub fn cmd_describe(args: &Args) -> Result<()> {
    let s = args.get_usize("s", 4)?;
    let k = args.get_usize("k", 2)?;
    let topology = Topology::parse(args.get_or("topology", "ring"))?;
    let alpha = match args.get("alpha") {
        Some(a) => Some(a.parse().map_err(|_| {
            crate::error::Error::Cli(format!("--alpha wants a number, got {a:?}"))
        })?),
        None => None,
    };
    args.finish()?;

    let grid = AgentGrid::build(s, k, topology, alpha)?;
    grid.check_assumption_3_1()?;
    println!("agent grid: S={s} data-groups x K={k} model-groups = {} agents", s * k);
    println!("model-group topology: {} (alpha = {:.4})", topology.name(), grid.alpha);
    println!("G^comm: {} edges, diameter {:?}", grid.total_edges(), grid.comm.diameter());
    println!("gamma = rho(P - 11^T/S) = {:.6}  (Lemma 2.1: < 1)", grid.gamma());
    println!(
        "mixing: disagreement x0.01 in ~{} gossip steps",
        crate::graph::mixing_time_estimate(grid.gamma(), 100.0)
    );
    let sched = Schedule::new(k);
    println!("staleness per module: {:?}", (0..k).map(|m| sched.staleness(m)).collect::<Vec<_>>());
    println!("warmup iterations: {}", sched.warmup_iters());
    println!("Assumption 3.1: OK");
    Ok(())
}

pub fn cmd_trace(args: &Args) -> Result<()> {
    let k = args.get_usize("k", 3)?;
    let iters = args.get_usize("iters", 12)?;
    args.finish()?;

    let sched = Schedule::new(k);
    println!("pipeline schedule, K={k} (Fig. 1): F<b> = forward batch b, B<b> = backward batch b");
    print!("{:<10}", "module\\t");
    for t in 0..iters {
        print!("{t:>12}");
    }
    println!();
    for m in 0..k {
        print!("{m:<10}");
        for t in 0..iters as i64 {
            let (f, b) = sched.trace_cell(t, m);
            let cell = match (f, b) {
                (Some(f), Some(b)) => format!("F{f}/B{b}"),
                (Some(f), None) => format!("F{f}"),
                (None, Some(b)) => format!("B{b}"),
                (None, None) => "-".into(),
            };
            print!("{cell:>12}");
        }
        println!();
    }
    Ok(())
}

/// `sgs trace-report FILE [--json]`: analyze a Chrome trace written by
/// `train`/`launch --trace-out` — per-module/per-phase time breakdown,
/// pipeline-fill vs steady-state split, and a straggler summary.
pub fn cmd_trace_report(args: &Args) -> Result<()> {
    let file = args
        .positional(0)
        .map(PathBuf::from)
        .ok_or_else(|| Error::Cli("usage: sgs trace-report FILE [--json]".into()))?;
    let json = args.get_bool("json");
    args.finish()?;

    let report = crate::obs::report::analyze_file(&file)?;
    if json {
        println!("{}", report.to_json().to_string_pretty());
    } else {
        print!("{}", report.render_text());
    }
    Ok(())
}

pub fn cmd_calibrate(args: &Args) -> Result<()> {
    let (kind, artifacts) = backend_flags(args)?;
    let model = model_of(args.get_or("model", "small"))?;
    let batch = args.get_usize("batch", 194)?;
    let reps = args.get_usize("reps", 5)?;
    let threads = args.get_usize("compute-threads", 0)?;
    args.finish()?;

    let backend = make_backend(kind, &artifacts, model.layers(), batch, threads)?;
    let cm = CostModel::calibrate(backend.as_ref(), reps);
    println!("cost model ({} backend, batch {batch}):", kind.as_str());
    for (i, (f, b)) in cm.fwd_s.iter().zip(&cm.bwd_s).enumerate() {
        println!("  layer {i}: fwd {:.3} ms, bwd {:.3} ms", f * 1e3, b * 1e3);
    }
    println!("  loss head: {:.3} ms", cm.loss_s * 1e3);
    println!("\ntiming table (per mini-batch iteration):");
    println!("{:<22} {:>12} {:>10}", "method", "iter", "speedup");
    let base = method_iter_s(&cm, 1, 1, 1);
    for (label, s, k, nb) in [
        ("centralized (1,1)", 1usize, 1usize, 1usize),
        ("decoupled (1,2)", 1, 2, 1),
        ("data-parallel (4,1)", 4, 1, 3),
        ("distributed (4,2)", 4, 2, 3),
    ] {
        let t = method_iter_s(&cm, s, k, nb);
        println!(
            "{:<22} {:>9.3} ms {:>9.2}x",
            label,
            t * 1e3,
            base / t
        );
    }
    Ok(())
}

/// `sgs serve --ckpt BASE [--listen HOST:PORT] [--http HOST:PORT]`: load a
/// checkpoint written by `train --ckpt-out` and answer prediction requests
/// with dynamic batching until SIGTERM/ctrl-c.
pub fn cmd_serve(args: &Args) -> Result<()> {
    let ckpt = args.get("ckpt").map(PathBuf::from).ok_or_else(|| {
        Error::Cli("serve needs --ckpt BASE (write one with train --ckpt-out)".into())
    })?;
    let listen = args.get("listen").map(String::from);
    let http = args.get("http").map(String::from);
    let trace_out = args.get("trace-out").map(PathBuf::from);
    let defaults = crate::config::ServeConfig::default();
    let mut cfg = crate::config::ServeConfig::default()
        .with_max_batch(args.get_usize("max-batch", defaults.max_batch)?)
        .with_max_wait_ms(args.get_u64("max-wait-ms", defaults.max_wait_ms)?)
        .with_compute_threads(args.get_usize("compute-threads", defaults.compute_threads)?);
    if let Some(codec) = args.get("codec") {
        cfg = cfg.with_codec(crate::net::WireCodec::parse(codec)?);
    }
    args.finish()?;

    // with no front requested, default to a Transport front on an
    // ephemeral loopback port
    let listen = match (&listen, &http) {
        (None, None) => Some("127.0.0.1:0".to_string()),
        _ => listen,
    };
    crate::net::worker::install_signal_handlers();
    let metrics = Arc::new(crate::obs::MetricsRegistry::new());
    let tracer = trace_out
        .as_ref()
        .map(|_| Arc::new(Tracer::new(DEFAULT_SPAN_CAPACITY)));
    println!(
        "serve: ckpt={} max_batch={} max_wait_ms={} codec={}",
        ckpt.display(),
        cfg.max_batch,
        cfg.max_wait_ms,
        cfg.codec.name()
    );
    let wall = WallClock::new();
    let opts = crate::serve::ServeOptions { cfg, ckpt, listen, http };
    let stats = crate::serve::run(&opts, &metrics, tracer.as_ref())?;
    println!(
        "serve: answered {} requests ({} rows) in {} batches",
        stats.requests, stats.rows, stats.batches
    );
    if let (Some(path), Some(tracer)) = (&trace_out, &tracer) {
        let meta = crate::obs::TraceMeta {
            engine: "serve".into(),
            s: 0,
            k: 0,
            iters: stats.batches as usize,
            warmup_iters: 0,
            iter_time_s: 0.0,
            wall_time_s: wall.elapsed_s(),
            workers: 0,
            clock: "wall",
        };
        crate::obs::write_chrome_trace(path, tracer, Some(&metrics), &meta)?;
        println!("wrote trace {}", path.display());
    }
    Ok(())
}

/// `sgs predict --connect HOST:PORT (--x F,F,... | --json FILE)`: send one
/// request batch over the Transport front and print the JSON reply.
pub fn cmd_predict(args: &Args) -> Result<()> {
    let addr = args
        .get("connect")
        .map(String::from)
        .ok_or_else(|| Error::Cli("predict needs --connect HOST:PORT".into()))?;
    let codec = match args.get("codec") {
        Some(c) => crate::net::WireCodec::parse(c)?,
        None => crate::net::WireCodec::Raw,
    };
    let repeat = args.get_usize("repeat", 1)?;
    let x = match (args.get("x"), args.get("json")) {
        (Some(csv), _) => {
            let vals = csv
                .split(',')
                .map(|v| {
                    v.trim().parse::<f32>().map_err(|_| {
                        Error::Cli(format!("--x wants comma-separated floats, got {v:?}"))
                    })
                })
                .collect::<Result<Vec<f32>>>()?;
            let d = vals.len();
            crate::tensor::Tensor::from_vec(&[1, d], vals)?
        }
        (None, Some(path)) => {
            let doc = crate::util::json::Json::from_file(Path::new(path))?;
            crate::serve::http::tensor_from_json(&doc)?
        }
        (None, None) => {
            return Err(Error::Cli(
                "predict needs --x F,F,... or --json FILE".into(),
            ))
        }
    };
    args.finish()?;

    let mut client = crate::serve::ServeClient::connect(&addr, codec)?;
    let mut last = None;
    for _ in 0..repeat.max(1) {
        last = Some(client.predict(&x)?);
    }
    client.close();
    if let Some(rep) = last {
        println!("{}", crate::serve::http::reply_to_json(&rep).to_string_compact());
    }
    Ok(())
}

/// `sgs top --connect HOST:PORT [--once] [--interval-ms MS]`: terminal
/// dashboard over a status server — a training run's `--status-addr` or
/// a serve instance's `--http`. Polls `GET /status` and renders
/// occupancy bars, staleness quantiles, stash hit rate, net rates, and
/// worker liveness (or QPS/latency for a serve target).
pub fn cmd_top(args: &Args) -> Result<()> {
    let addr = args
        .get("connect")
        .map(String::from)
        .ok_or_else(|| Error::Cli("top needs --connect HOST:PORT (a --status-addr)".into()))?;
    let once = args.get_bool("once");
    let interval_ms = args.get_u64("interval-ms", 1000)?.max(50);
    args.finish()?;

    let timeout = Duration::from_secs(2);
    let clock = WallClock::new();
    let mut prev: Option<(crate::util::json::Json, f64)> = None;
    let flag = crate::net::worker::shutdown_flag();
    crate::net::worker::install_signal_handlers();
    loop {
        let (code, body) = crate::serve::http::http_get(&addr, "/status", timeout)?;
        if code != 200 {
            return Err(Error::Net(format!("{addr} /status returned {code}: {body}")));
        }
        let doc = crate::util::json::Json::parse(&body)?;
        let now = clock.elapsed_s();
        let frame = crate::monitor::render_status(
            &doc,
            prev.as_ref().map(|(d, t)| (d, now - t)),
        );
        if once {
            print!("{frame}");
            return Ok(());
        }
        // clear screen + home, then draw the frame
        print!("\x1b[2J\x1b[H{frame}");
        std::io::stdout().flush()?;
        prev = Some((doc, now));
        let mut waited = Duration::ZERO;
        let slice = Duration::from_millis(50);
        while waited < Duration::from_millis(interval_ms) {
            if flag.load(std::sync::atomic::Ordering::SeqCst) {
                return Ok(());
            }
            std::thread::sleep(slice);
            waited += slice;
        }
    }
}

pub fn dispatch(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "train" => cmd_train(&args),
        "compare" => cmd_compare(&args),
        "worker" => cmd_worker(&args),
        "launch" => cmd_launch(&args),
        "describe" => cmd_describe(&args),
        "trace" => cmd_trace(&args),
        "trace-report" => cmd_trace_report(&args),
        "calibrate" => cmd_calibrate(&args),
        "serve" => cmd_serve(&args),
        "predict" => cmd_predict(&args),
        "top" => cmd_top(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(crate::error::Error::Cli(format!(
            "unknown command {other:?}\n{USAGE}"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn describe_runs() {
        dispatch(&argv("describe --s 4 --k 2 --topology ring")).unwrap();
    }

    #[test]
    fn trace_runs() {
        dispatch(&argv("trace --k 3 --iters 8")).unwrap();
    }

    #[test]
    fn train_tiny_native() {
        dispatch(&argv(
            "train --model tiny --s 2 --k 2 --iters 10 --batch 8 --dataset-n 200 \
             --eval-every 5 --delta-every 5 --lr const:0.1",
        ))
        .unwrap();
    }

    #[test]
    fn train_tiny_threaded_with_events() {
        let dir = std::env::temp_dir().join("sgs_cli_events");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.jsonl");
        dispatch(&argv(&format!(
            "train --model tiny --s 2 --k 2 --iters 8 --batch 8 --dataset-n 200 \
             --engine threaded --lr const:0.1 --events-out {}",
            path.display()
        )))
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 8);
        for line in text.lines() {
            let j = crate::util::json::Json::parse(line).unwrap();
            assert!(j.get("t").unwrap().as_usize().is_ok());
            assert!(j.get("staleness").unwrap().as_arr().is_ok());
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn train_cnn_preset() {
        // the CIFAR-geometry CNN on a synthetic 3072-dim dataset, split
        // across 2 modules — the paper's headline workload end-to-end
        dispatch(&argv(
            "train --model cnn --s 1 --k 2 --iters 3 --batch 4 --dataset-n 64 \
             --eval-every 0 --delta-every 0 --lr const:0.05",
        ))
        .unwrap();
    }

    #[test]
    fn config_file_stack_model_survives_flag_defaults() {
        // a --config file carrying a layer-spec stack must not be stomped
        // by the --model default when the flag is absent
        let dir = std::env::temp_dir().join("sgs_cli_stack_cfg");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cnn.json");
        let mut cfg = ExperimentConfig::default();
        cfg.model = crate::config::ModelSpec::Stack(
            StackModel::new(2, 6, 6, ["conv3x3:3", "maxpool", "flatten", "linear:3"], 3)
                .unwrap(),
        );
        cfg.s = 1;
        cfg.k = 2;
        cfg.iters = 2;
        cfg.batch = 4;
        cfg.dataset_n = 40;
        cfg.eval_every = 0;
        cfg.delta_every = 0;
        cfg.save(&path).unwrap();

        let a = Args::parse(&argv(&format!("train --config {}", path.display()))).unwrap();
        let parsed = config_from_args(&a).unwrap();
        assert_eq!(parsed.model, cfg.model, "config-file model preserved");
        dispatch(&argv(&format!("train --config {}", path.display()))).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn train_with_compensation_strategies() {
        for comp in ["dc:0.04", "accum:2"] {
            dispatch(&argv(&format!(
                "train --model tiny --s 2 --k 2 --iters 8 --batch 8 --dataset-n 200 \
                 --compensate {comp} --lr const:0.1"
            )))
            .unwrap();
        }
        // bad strategy strings surface as CLI config errors
        assert!(dispatch(&argv(
            "train --model tiny --s 1 --k 1 --iters 2 --batch 8 --dataset-n 100 \
             --compensate warp:9"
        ))
        .is_err());
    }

    #[test]
    fn config_from_args_parses_compensate() {
        let a = Args::parse(&argv(
            "train --s 2 --k 2 --iters 10 --batch 8 --dataset-n 200 --model tiny \
             --compensate accum:3",
        ))
        .unwrap();
        let cfg = config_from_args(&a).unwrap();
        assert_eq!(
            cfg.compensate,
            crate::compensate::CompensatorKind::Accumulate { n: 3 }
        );
    }

    #[test]
    fn train_trace_out_then_trace_report_roundtrip() {
        let dir = std::env::temp_dir().join("sgs_cli_trace");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        dispatch(&argv(&format!(
            "train --model tiny --s 2 --k 2 --iters 8 --batch 8 --dataset-n 200 \
             --engine threaded --lr const:0.1 --trace-out {}",
            path.display()
        )))
        .unwrap();
        let doc = crate::util::json::Json::from_file(&path).unwrap();
        assert!(doc.get("traceEvents").unwrap().as_arr().unwrap().len() > 4);
        // the analyzer accepts what the exporter wrote, in both renderings
        dispatch(&argv(&format!("trace-report {}", path.display()))).unwrap();
        dispatch(&argv(&format!("trace-report {} --json", path.display()))).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn trace_report_wants_a_file() {
        assert!(dispatch(&argv("trace-report")).is_err());
        assert!(dispatch(&argv("trace-report does_not_exist.json")).is_err());
    }

    #[test]
    fn train_ckpt_out_then_predictor_loads_it() {
        let dir = std::env::temp_dir().join("sgs_cli_ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("final");
        dispatch(&argv(&format!(
            "train --model tiny --s 2 --k 2 --iters 6 --batch 8 --dataset-n 200 \
             --lr const:0.1 --ckpt-out {}",
            base.display()
        )))
        .unwrap();
        assert!(base.with_extension("json").exists());
        assert!(base.with_extension("bin").exists());
        let mut p = crate::session::Predictor::from_checkpoint(&base, 4, 1).unwrap();
        let x = crate::tensor::Tensor::zeros(&[2, p.d_in()]);
        let mut logits = crate::tensor::Tensor::empty();
        p.predict_into(&x, &mut logits).unwrap();
        assert_eq!(logits.shape(), &[2, p.classes()]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn serve_and_predict_validate_their_flags() {
        // all of these fail before any socket blocks
        assert!(dispatch(&argv("serve")).is_err(), "--ckpt is required");
        assert!(
            dispatch(&argv("serve --ckpt nope --max-batch 0")).is_err(),
            "config validation rejects max_batch 0"
        );
        assert!(
            dispatch(&argv("serve --ckpt does_not_exist")).is_err(),
            "missing checkpoint surfaces as an error"
        );
        assert!(dispatch(&argv("predict")).is_err(), "--connect is required");
        assert!(
            dispatch(&argv("predict --connect 127.0.0.1:9 --x a,b")).is_err(),
            "--x wants floats"
        );
        assert!(
            dispatch(&argv("predict --connect 127.0.0.1:9")).is_err(),
            "an input is required"
        );
    }

    #[test]
    fn train_dist_engine_with_in_process_workers() {
        // the full coordinator/worker protocol over the Local transport,
        // end-to-end through the CLI
        dispatch(&argv(
            "train --model tiny --s 2 --k 2 --iters 6 --batch 8 --dataset-n 200 \
             --engine dist --workers 2 --lr const:0.1",
        ))
        .unwrap();
    }

    #[test]
    fn train_dist_engine_without_placement_errors() {
        let err = dispatch(&argv(
            "train --model tiny --s 1 --k 1 --iters 2 --batch 8 --dataset-n 100 \
             --engine dist",
        ))
        .unwrap_err();
        assert!(matches!(err, crate::error::Error::Config(_)), "{err}");
        assert!(err.to_string().contains("dist"), "{err}");
    }

    #[test]
    fn workers_flag_rejects_non_dist_engines_and_mismatches() {
        assert!(dispatch(&argv(
            "train --model tiny --s 1 --k 1 --iters 2 --batch 8 --dataset-n 100 \
             --workers 2",
        ))
        .is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.placement = Some(Placement::even(2, cfg.s, cfg.k).unwrap());
        let mut c = cfg.clone();
        c.placement = Some(Placement::even(4, cfg.s, cfg.k).unwrap());
        assert!(apply_workers_flag(&mut c, EngineKind::Dist, 2).is_err());
        let mut c = cfg;
        assert!(apply_workers_flag(&mut c, EngineKind::Dist, 2).is_ok());
    }

    #[test]
    fn train_with_pinned_compute_threads() {
        for threads in ["1", "2"] {
            dispatch(&argv(&format!(
                "train --model tiny --s 2 --k 2 --iters 6 --batch 8 --dataset-n 200 \
                 --compute-threads {threads} --lr const:0.1"
            )))
            .unwrap();
        }
    }

    #[test]
    fn train_accepts_uppercase_backend() {
        dispatch(&argv(
            "train --model tiny --s 1 --k 1 --iters 3 --batch 8 --dataset-n 100 \
             --backend NATIVE --lr const:0.1",
        ))
        .unwrap();
    }

    #[test]
    fn unknown_command_errors() {
        assert!(dispatch(&argv("frobnicate")).is_err());
    }

    #[test]
    fn config_from_args_respects_flags() {
        let a = Args::parse(&argv(
            "train --s 3 --k 2 --iters 50 --batch 16 --dataset-n 600 --model tiny --topology star",
        ))
        .unwrap();
        let cfg = config_from_args(&a).unwrap();
        assert_eq!((cfg.s, cfg.k, cfg.iters, cfg.batch), (3, 2, 50, 16));
        assert_eq!(cfg.topology, Topology::Star);
        assert_eq!(cfg.model, ModelShape::tiny().into());
    }
}
