//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `sgs <command> [FILE]... [--flag value]... [--switch]...`
//! Flags and positionals are declared by the command handlers via typed
//! getters; anything nobody consumed is an error (catches typos).

use std::collections::BTreeMap;

use crate::error::{Error, Result};

#[derive(Debug, Clone)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
    positionals: Vec<String>,
    consumed: std::cell::RefCell<std::collections::BTreeSet<String>>,
    consumed_pos: std::cell::RefCell<std::collections::BTreeSet<usize>>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        if argv.is_empty() {
            return Err(Error::Cli("missing command".into()));
        }
        let command = argv[0].clone();
        let mut flags = BTreeMap::new();
        let mut positionals = Vec::new();
        let mut i = 1;
        while i < argv.len() {
            let arg = &argv[i];
            match arg.strip_prefix("--") {
                Some(name) => {
                    if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                        flags.insert(name.to_string(), argv[i + 1].clone());
                        i += 2;
                    } else {
                        flags.insert(name.to_string(), "true".into()); // bare switch
                        i += 1;
                    }
                }
                None => {
                    positionals.push(arg.clone());
                    i += 1;
                }
            }
        }
        Ok(Args {
            command,
            flags,
            positionals,
            consumed: Default::default(),
            consumed_pos: Default::default(),
        })
    }

    fn mark(&self, name: &str) {
        self.consumed.borrow_mut().insert(name.to_string());
    }

    /// The `idx`-th bare (non-`--flag`) argument, if present.
    pub fn positional(&self, idx: usize) -> Option<&str> {
        self.consumed_pos.borrow_mut().insert(idx);
        self.positionals.get(idx).map(|s| s.as_str())
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.mark(name);
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Cli(format!("--{name} wants an integer, got {v:?}"))),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::Cli(format!("--{name} wants a number, got {v:?}"))),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        Ok(self.get_usize(name, default as usize)? as u64)
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }

    /// Call after all getters: errors on flags or positionals nobody
    /// consumed.
    pub fn finish(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<&String> = self
            .flags
            .keys()
            .filter(|k| !consumed.contains(*k))
            .collect();
        if !unknown.is_empty() {
            return Err(Error::Cli(format!("unknown flags: {unknown:?}")));
        }
        let consumed_pos = self.consumed_pos.borrow();
        let stray: Vec<&String> = self
            .positionals
            .iter()
            .enumerate()
            .filter(|(i, _)| !consumed_pos.contains(i))
            .map(|(_, v)| v)
            .collect();
        if stray.is_empty() {
            Ok(())
        } else {
            Err(Error::Cli(format!("unexpected arguments: {stray:?}")))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_flags_and_switches() {
        let a = Args::parse(&argv("train --iters 100 --s 4 --verbose --lr const:0.1")).unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.get_usize("iters", 0).unwrap(), 100);
        assert_eq!(a.get_usize("s", 0).unwrap(), 4);
        assert_eq!(a.get("lr"), Some("const:0.1"));
        assert!(a.get_bool("verbose"));
        assert_eq!(a.get_usize("k", 2).unwrap(), 2);
        a.finish().unwrap();
    }

    #[test]
    fn rejects_unknown_flags() {
        let a = Args::parse(&argv("train --bogus 1")).unwrap();
        let _ = a.get("iters");
        assert!(a.finish().is_err());
    }

    #[test]
    fn rejects_bad_values() {
        let a = Args::parse(&argv("train --iters banana")).unwrap();
        assert!(a.get_usize("iters", 0).is_err());
    }

    #[test]
    fn rejects_unconsumed_positionals() {
        let a = Args::parse(&argv("train oops")).unwrap();
        assert!(a.finish().is_err());
        assert!(Args::parse(&[]).is_err());
    }

    #[test]
    fn consumed_positionals_pass_finish() {
        let a = Args::parse(&argv("trace-report trace.json --json")).unwrap();
        assert_eq!(a.positional(0), Some("trace.json"));
        assert!(a.get_bool("json"));
        a.finish().unwrap();
        assert_eq!(a.positional(1), None);
    }
}
