//! Weight initialization. He (Kaiming) normal for ReLU stacks — std
//! sqrt(2/d_in) — with zero biases, matching the python test fixtures'
//! 1/sqrt(d_in) scale closely enough that both backends start in the same
//! loss regime.

use crate::nn::layer::LayerShape;
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

/// W ~ N(0, 2/d_in), shaped [d_in, d_out].
pub fn he_init(rng: &mut Pcg32, d_in: usize, d_out: usize) -> Tensor {
    let std = (2.0 / d_in as f32).sqrt();
    let mut w = Tensor::zeros(&[d_in, d_out]);
    rng.fill_normal(w.data_mut(), std);
    w
}

/// W ~ N(0, 2/(d_in + d_out)) (Glorot), shaped [d_in, d_out].
pub fn glorot_init(rng: &mut Pcg32, d_in: usize, d_out: usize) -> Tensor {
    let std = (2.0 / (d_in + d_out) as f32).sqrt();
    let mut w = Tensor::zeros(&[d_in, d_out]);
    rng.fill_normal(w.data_mut(), std);
    w
}

/// Initialize a full layer stack: He weights, zero biases. Conv layers get
/// He fan-in 9·c_in over their `[9·c_in, c_out]` im2col weights;
/// parameter-free layers (maxpool/flatten) keep `[0, 0]`/`[0]` placeholders
/// so every layer owns the uniform (W, b) slot the plumbing expects.
pub fn init_params(rng: &mut Pcg32, layers: &[LayerShape]) -> Vec<(Tensor, Tensor)> {
    layers
        .iter()
        .map(|l| {
            let [rows, cols] = l.w_shape();
            let w = if rows * cols > 0 {
                he_init(rng, rows, cols)
            } else {
                Tensor::zeros(&[rows, cols])
            };
            (w, Tensor::zeros(&[l.b_len()]))
        })
        .collect()
}

/// Flatten (W, b) pairs into one parameter vector (W row-major, then b) —
/// the layout the gossip/consensus layer mixes.
pub fn flatten_params(params: &[(Tensor, Tensor)]) -> Tensor {
    let total: usize = params.iter().map(|(w, b)| w.len() + b.len()).sum();
    let mut flat = Vec::with_capacity(total);
    for (w, b) in params {
        flat.extend_from_slice(w.data());
        flat.extend_from_slice(b.data());
    }
    Tensor::from_vec(&[total], flat).unwrap()
}

/// Inverse of `flatten_params` for a given layer stack.
pub fn unflatten_params(flat: &Tensor, layers: &[LayerShape]) -> Vec<(Tensor, Tensor)> {
    let mut out = Vec::with_capacity(layers.len());
    let mut off = 0;
    for l in layers {
        let [rows, cols] = l.w_shape();
        let wlen = rows * cols;
        let w = Tensor::from_vec(&[rows, cols], flat.data()[off..off + wlen].to_vec()).unwrap();
        off += wlen;
        let blen = l.b_len();
        let b = Tensor::from_vec(&[blen], flat.data()[off..off + blen].to_vec()).unwrap();
        off += blen;
        out.push((w, b));
    }
    debug_assert_eq!(off, flat.len());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::layer::{resmlp_layers, LayerKind};

    #[test]
    fn he_std_is_right() {
        let mut rng = Pcg32::new(1);
        let w = he_init(&mut rng, 512, 256);
        let xs: Vec<f64> = w.data().iter().map(|&x| x as f64).collect();
        let sd = crate::util::stddev(&xs);
        let want = (2.0f64 / 512.0).sqrt();
        assert!((sd - want).abs() < 0.002, "sd={sd} want={want}");
    }

    #[test]
    fn init_params_shapes() {
        let mut rng = Pcg32::new(2);
        let layers = resmlp_layers(8, 4, 2, 3);
        let params = init_params(&mut rng, &layers);
        assert_eq!(params.len(), 4);
        assert_eq!(params[0].0.shape(), &[8, 4]);
        assert_eq!(params[3].0.shape(), &[4, 3]);
        assert!(params.iter().all(|(_, b)| b.data().iter().all(|&x| x == 0.0)));
    }

    #[test]
    fn flatten_roundtrip() {
        let mut rng = Pcg32::new(3);
        let layers = vec![
            LayerShape::new(LayerKind::Relu, 3, 2).unwrap(),
            LayerShape::new(LayerKind::Linear, 2, 4).unwrap(),
        ];
        let params = init_params(&mut rng, &layers);
        let flat = flatten_params(&params);
        assert_eq!(flat.len(), 3 * 2 + 2 + 2 * 4 + 4);
        let back = unflatten_params(&flat, &layers);
        for ((w, b), (w2, b2)) in params.iter().zip(&back) {
            assert_eq!(w, w2);
            assert_eq!(b, b2);
        }
    }
}
