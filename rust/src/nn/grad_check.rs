//! Finite-difference gradient checking — the ground truth beneath both
//! backends. Central differences on randomly sampled coordinates (checking
//! every coordinate of a 100k-param net would drown the test suite).

use crate::nn::layer::LayerShape;
use crate::nn::{full_backward, full_loss, layer_bwd_into, layer_fwd_into, BwdScratch, FwdScratch};
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

/// Max relative error between analytic and finite-difference gradients of a
/// scalarized single layer: f = Σ g_out ⊙ layer(x, w, b). Drives the same
/// in-place workspace kernels the backends run (any [`LayerShape`] kind,
/// conv/pool/flatten included), so the finite-difference oracle pins
/// exactly the production code path. Parameter-free layers simply have no
/// W/b coordinates to probe.
pub fn check_layer(
    x: &Tensor,
    w: &Tensor,
    b: &Tensor,
    layer: LayerShape,
    eps: f32,
    rng: &mut Pcg32,
) -> f64 {
    let mut h_out = Tensor::empty();
    let mut fs = FwdScratch::new();
    layer_fwd_into(x, w, b, layer, &mut h_out, &mut fs, 1);
    // fixed co-vector so the scalar is smooth in the parameters
    let mut g_out = Tensor::zeros(h_out.shape());
    rng.fill_normal(g_out.data_mut(), 1.0);

    let (mut g_x, mut g_w, mut g_b) = (Tensor::empty(), Tensor::empty(), Tensor::empty());
    let mut scratch = BwdScratch::new();
    layer_bwd_into(
        x,
        w,
        &h_out,
        &g_out,
        layer,
        &mut g_x,
        &mut g_w,
        &mut g_b,
        &mut scratch,
        1,
    );

    let scalar = |x: &Tensor, w: &Tensor, b: &Tensor| -> f64 {
        let mut h = Tensor::empty();
        let mut fs = FwdScratch::new();
        layer_fwd_into(x, w, b, layer, &mut h, &mut fs, 1);
        h.data()
            .iter()
            .zip(g_out.data())
            .map(|(&a, &c)| (a as f64) * (c as f64))
            .sum()
    };

    let mut worst: f64 = 0.0;
    let mut probe = |analytic: &Tensor, which: usize| {
        let n_samples = analytic.len().min(12);
        for _ in 0..n_samples {
            let idx = rng.below(analytic.len());
            let (mut xp, mut wp, mut bp) = (x.clone(), w.clone(), b.clone());
            let (mut xm, mut wm, mut bm) = (x.clone(), w.clone(), b.clone());
            let target_p = match which {
                0 => &mut xp,
                1 => &mut wp,
                _ => &mut bp,
            };
            target_p.data_mut()[idx] += eps;
            let target_m = match which {
                0 => &mut xm,
                1 => &mut wm,
                _ => &mut bm,
            };
            target_m.data_mut()[idx] -= eps;
            let fd = (scalar(&xp, &wp, &bp) - scalar(&xm, &wm, &bm)) / (2.0 * eps as f64);
            let an = analytic.data()[idx] as f64;
            let denom = fd.abs().max(an.abs()).max(1.0);
            worst = worst.max((fd - an).abs() / denom);
        }
    };
    probe(&g_x, 0);
    probe(&g_w, 1);
    probe(&g_b, 2);
    worst
}

/// Max relative error between `full_backward` and central differences on
/// sampled coordinates of every layer's (W, b).
pub fn check_full(
    x: &Tensor,
    onehot: &Tensor,
    params: &[(Tensor, Tensor)],
    layers: &[LayerShape],
    eps: f32,
    rng: &mut Pcg32,
) -> f64 {
    let (_, grads) = full_backward(x, onehot, params, layers);
    let mut worst: f64 = 0.0;
    for li in 0..params.len() {
        for which in 0..2usize {
            let analytic = if which == 0 { &grads[li].0 } else { &grads[li].1 };
            let n_samples = analytic.len().min(8);
            for _ in 0..n_samples {
                let idx = rng.below(analytic.len());
                let mut pp: Vec<(Tensor, Tensor)> = params.to_vec();
                let mut pm: Vec<(Tensor, Tensor)> = params.to_vec();
                if which == 0 {
                    pp[li].0.data_mut()[idx] += eps;
                    pm[li].0.data_mut()[idx] -= eps;
                } else {
                    pp[li].1.data_mut()[idx] += eps;
                    pm[li].1.data_mut()[idx] -= eps;
                }
                let fd = (full_loss(x, onehot, &pp, layers) as f64
                    - full_loss(x, onehot, &pm, layers) as f64)
                    / (2.0 * eps as f64);
                let an = analytic.data()[idx] as f64;
                let denom = fd.abs().max(an.abs()).max(1e-2);
                worst = worst.max((fd - an).abs() / denom);
            }
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::init::{he_init, init_params};
    use crate::nn::layer::{resmlp_layers, LayerKind};

    #[test]
    fn linear_layer_fd_exact() {
        // linear layers are exactly linear -> central difference is exact
        let mut rng = Pcg32::new(9);
        let x = {
            let mut t = Tensor::zeros(&[3, 4]);
            rng.fill_normal(t.data_mut(), 1.0);
            t
        };
        let w = he_init(&mut rng, 4, 5);
        let b = Tensor::zeros(&[5]);
        let layer = LayerShape::new(LayerKind::Linear, 4, 5).unwrap();
        let err = check_layer(&x, &w, &b, layer, 1e-2, &mut rng);
        assert!(err < 1e-3, "{err}");
    }

    /// |N(0, std)| + floor: strictly positive samples, so every ReLU sits
    /// far from its kink and the finite differences stay exact.
    fn fill_positive(rng: &mut Pcg32, t: &mut Tensor, std: f32, floor: f32) {
        rng.fill_normal(t.data_mut(), std);
        for v in t.data_mut() {
            *v = v.abs() + floor;
        }
    }

    #[test]
    fn conv_fd_exact_on_active_relu() {
        // positive x, W, b keep every pre-activation strictly positive, so
        // the conv layer is bilinear on the probe neighbourhood and the
        // central difference is exact — this pins the im2col linear algebra
        // (g_x via col2im, g_w via col^T, g_b) without kink noise. The ReLU
        // mask itself is pinned exactly in conv::tests.
        let mut rng = Pcg32::new(21);
        let conv = LayerShape::conv3x3(2, 4, 4, 3).unwrap();
        let mut x = Tensor::zeros(&[3, conv.d_in]);
        fill_positive(&mut rng, &mut x, 1.0, 0.5);
        let mut w = Tensor::zeros(&[18, 3]);
        fill_positive(&mut rng, &mut w, 0.3, 0.05);
        let mut b = Tensor::zeros(&[3]);
        fill_positive(&mut rng, &mut b, 0.1, 0.2);
        let err = check_layer(&x, &w, &b, conv, 1e-3, &mut rng);
        assert!(err < 1e-2, "conv3x3 fd mismatch {err}");
    }

    #[test]
    fn maxpool_and_flatten_fd() {
        // maxpool input: distinct values with gap 0.1 ≫ 2·eps, so the
        // window argmax never flips inside the probe neighbourhood and the
        // pooled function is exactly linear there
        let mut rng = Pcg32::new(23);
        let pool = LayerShape::maxpool2(3, 4, 4).unwrap();
        let n = 3 * pool.d_in;
        let mut vals = vec![0.0f32; n];
        for (p, v) in vals.iter_mut().enumerate() {
            *v = ((p * 37) % n) as f32 * 0.1;
        }
        let x = Tensor::from_vec(&[3, pool.d_in], vals).unwrap();
        let empty_w = Tensor::zeros(&[0, 0]);
        let empty_b = Tensor::zeros(&[0]);
        let err = check_layer(&x, &empty_w, &empty_b, pool, 1e-3, &mut rng);
        assert!(err < 1e-2, "maxpool fd mismatch {err}");

        let flat = LayerShape::flatten(3, 4, 4).unwrap();
        let mut x = Tensor::zeros(&[3, flat.d_in]);
        rng.fill_normal(x.data_mut(), 1.0);
        let err = check_layer(&x, &empty_w, &empty_b, flat, 1e-3, &mut rng);
        assert!(err < 1e-3, "flatten fd mismatch {err}");
    }

    #[test]
    fn full_cnn_fd_small() {
        // a conv → flatten → dense-head stack against central differences
        // on every parametrized layer. All-positive weights/inputs keep
        // every ReLU strictly active, so the network is smooth on the probe
        // neighbourhood (softmax-xent is smooth everywhere); maxpool's
        // gradient has its own exact checks above.
        let mut rng = Pcg32::new(22);
        let layers =
            crate::nn::build_stack(2, 4, 4, &["conv3x3:3", "flatten", "relu:6", "linear:3"]).unwrap();
        // small positive weights: ReLUs strictly active yet the logits stay
        // in the healthy softmax range (saturation would starve the FD
        // numerator below f32 resolution)
        let mut params = init_params(&mut rng, &layers);
        for (w, b) in params.iter_mut() {
            for v in w.data_mut() {
                *v = v.abs() * 0.1 + 0.01;
            }
            for v in b.data_mut() {
                *v = 0.1;
            }
        }
        let mut x = Tensor::zeros(&[4, 32]);
        fill_positive(&mut rng, &mut x, 0.5, 0.1);
        let mut onehot = Tensor::zeros(&[4, 3]);
        for i in 0..4 {
            let c = rng.below(3);
            onehot.data_mut()[i * 3 + c] = 1.0;
        }
        let err = check_full(&x, &onehot, &params, &layers, 1e-3, &mut rng);
        assert!(err < 2e-2, "{err}");
    }

    #[test]
    fn full_net_fd_small() {
        let mut rng = Pcg32::new(11);
        let layers = resmlp_layers(6, 5, 1, 3);
        let params = init_params(&mut rng, &layers);
        let mut x = Tensor::zeros(&[4, 6]);
        rng.fill_normal(x.data_mut(), 1.0);
        let mut onehot = Tensor::zeros(&[4, 3]);
        for i in 0..4 {
            let c = rng.below(3);
            onehot.data_mut()[i * 3 + c] = 1.0;
        }
        let err = check_full(&x, &onehot, &params, &layers, 1e-3, &mut rng);
        assert!(err < 2e-2, "{err}");
    }
}
