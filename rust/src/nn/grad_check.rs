//! Finite-difference gradient checking — the ground truth beneath both
//! backends. Central differences on randomly sampled coordinates (checking
//! every coordinate of a 100k-param net would drown the test suite).

use crate::nn::layer::LayerShape;
use crate::nn::{dense_bwd_into, dense_fwd_into, full_backward, full_loss, BwdScratch};
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

/// Max relative error between analytic and finite-difference gradients of a
/// scalarized single layer: f = Σ g_out ⊙ layer(x, w, b). Drives the same
/// in-place workspace kernels the backends run, so the finite-difference
/// oracle pins exactly the production code path.
pub fn check_layer(
    x: &Tensor,
    w: &Tensor,
    b: &Tensor,
    layer: LayerShape,
    eps: f32,
    rng: &mut Pcg32,
) -> f64 {
    let mut h_out = Tensor::empty();
    dense_fwd_into(x, w, b, layer.kind, &mut h_out, 1);
    // fixed co-vector so the scalar is smooth in the parameters
    let mut g_out = Tensor::zeros(h_out.shape());
    rng.fill_normal(g_out.data_mut(), 1.0);

    let (mut g_x, mut g_w, mut g_b) = (Tensor::empty(), Tensor::empty(), Tensor::empty());
    let mut scratch = BwdScratch::new();
    dense_bwd_into(
        x,
        w,
        &h_out,
        &g_out,
        layer.kind,
        &mut g_x,
        &mut g_w,
        &mut g_b,
        &mut scratch,
        1,
    );

    let scalar = |x: &Tensor, w: &Tensor, b: &Tensor| -> f64 {
        let mut h = Tensor::empty();
        dense_fwd_into(x, w, b, layer.kind, &mut h, 1);
        h.data()
            .iter()
            .zip(g_out.data())
            .map(|(&a, &c)| (a as f64) * (c as f64))
            .sum()
    };

    let mut worst: f64 = 0.0;
    let mut probe = |analytic: &Tensor, which: usize| {
        let n_samples = analytic.len().min(12);
        for _ in 0..n_samples {
            let idx = rng.below(analytic.len());
            let (mut xp, mut wp, mut bp) = (x.clone(), w.clone(), b.clone());
            let (mut xm, mut wm, mut bm) = (x.clone(), w.clone(), b.clone());
            let target_p = match which {
                0 => &mut xp,
                1 => &mut wp,
                _ => &mut bp,
            };
            target_p.data_mut()[idx] += eps;
            let target_m = match which {
                0 => &mut xm,
                1 => &mut wm,
                _ => &mut bm,
            };
            target_m.data_mut()[idx] -= eps;
            let fd = (scalar(&xp, &wp, &bp) - scalar(&xm, &wm, &bm)) / (2.0 * eps as f64);
            let an = analytic.data()[idx] as f64;
            let denom = fd.abs().max(an.abs()).max(1.0);
            worst = worst.max((fd - an).abs() / denom);
        }
    };
    probe(&g_x, 0);
    probe(&g_w, 1);
    probe(&g_b, 2);
    worst
}

/// Max relative error between `full_backward` and central differences on
/// sampled coordinates of every layer's (W, b).
pub fn check_full(
    x: &Tensor,
    onehot: &Tensor,
    params: &[(Tensor, Tensor)],
    layers: &[LayerShape],
    eps: f32,
    rng: &mut Pcg32,
) -> f64 {
    let (_, grads) = full_backward(x, onehot, params, layers);
    let mut worst: f64 = 0.0;
    for li in 0..params.len() {
        for which in 0..2usize {
            let analytic = if which == 0 { &grads[li].0 } else { &grads[li].1 };
            let n_samples = analytic.len().min(8);
            for _ in 0..n_samples {
                let idx = rng.below(analytic.len());
                let mut pp: Vec<(Tensor, Tensor)> = params.to_vec();
                let mut pm: Vec<(Tensor, Tensor)> = params.to_vec();
                if which == 0 {
                    pp[li].0.data_mut()[idx] += eps;
                    pm[li].0.data_mut()[idx] -= eps;
                } else {
                    pp[li].1.data_mut()[idx] += eps;
                    pm[li].1.data_mut()[idx] -= eps;
                }
                let fd = (full_loss(x, onehot, &pp, layers) as f64
                    - full_loss(x, onehot, &pm, layers) as f64)
                    / (2.0 * eps as f64);
                let an = analytic.data()[idx] as f64;
                let denom = fd.abs().max(an.abs()).max(1e-2);
                worst = worst.max((fd - an).abs() / denom);
            }
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::init::{he_init, init_params};
    use crate::nn::layer::{resmlp_layers, LayerKind};

    #[test]
    fn linear_layer_fd_exact() {
        // linear layers are exactly linear -> central difference is exact
        let mut rng = Pcg32::new(9);
        let x = {
            let mut t = Tensor::zeros(&[3, 4]);
            rng.fill_normal(t.data_mut(), 1.0);
            t
        };
        let w = he_init(&mut rng, 4, 5);
        let b = Tensor::zeros(&[5]);
        let layer = LayerShape::new(LayerKind::Linear, 4, 5).unwrap();
        let err = check_layer(&x, &w, &b, layer, 1e-2, &mut rng);
        assert!(err < 1e-3, "{err}");
    }

    #[test]
    fn full_net_fd_small() {
        let mut rng = Pcg32::new(11);
        let layers = resmlp_layers(6, 5, 1, 3);
        let params = init_params(&mut rng, &layers);
        let mut x = Tensor::zeros(&[4, 6]);
        rng.fill_normal(x.data_mut(), 1.0);
        let mut onehot = Tensor::zeros(&[4, 3]);
        for i in 0..4 {
            let c = rng.below(3);
            onehot.data_mut()[i * 3 + c] = 1.0;
        }
        let err = check_full(&x, &onehot, &params, &layers, 1e-3, &mut rng);
        assert!(err < 2e-2, "{err}");
    }
}
