//! Layer vocabulary shared with the compile path.
//!
//! `LayerKind` string forms must stay in sync with
//! `python/compile/kernels/ref.py` (KIND_*) and the manifest emitted by
//! `python/compile/aot.py`. The convolutional kinds (`conv3x3`, `maxpool`,
//! `flatten`) are native-backend-only: no AOT artifacts exist for them yet,
//! and the manifest loader rejects them until they do.
//!
//! Activations stay 2-D `[B, d]` tensors everywhere — the spatial kinds
//! interpret the flattened vector in NCHW order (channel-major planes),
//! carried by the [`Spatial`] descriptor alongside the dense `d_in`/`d_out`
//! vocabulary, so the pipeline/gossip/checkpoint plumbing never has to know
//! about images.

use crate::error::{Error, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerKind {
    /// z = x·W + b
    Linear,
    /// relu(z)
    Relu,
    /// relu(z) + x  (requires d_in == d_out)
    Residual,
    /// relu(conv3x3(x, W) + b): 3×3 kernel, stride 1, zero-pad 1 (same H, W)
    Conv3x3,
    /// 2×2 max pooling, stride 2 (requires even H, W); no parameters
    MaxPool2x2,
    /// NCHW → dense marker; identity on the flat buffer, no parameters
    Flatten,
}

impl LayerKind {
    /// Parse a layer-kind name — trimmed and case-folded, like
    /// `BackendKind::parse` / `OptimizerKind::parse`. Unknown names are a
    /// config error carrying the offending string.
    pub fn parse(s: &str) -> Result<LayerKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "linear" => Ok(LayerKind::Linear),
            "relu" => Ok(LayerKind::Relu),
            "residual" => Ok(LayerKind::Residual),
            "conv3x3" => Ok(LayerKind::Conv3x3),
            "maxpool" => Ok(LayerKind::MaxPool2x2),
            "flatten" => Ok(LayerKind::Flatten),
            _ => Err(Error::Config(format!(
                "unknown layer kind {s:?} \
                 (want linear|relu|residual|conv3x3|maxpool|flatten)"
            ))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            LayerKind::Linear => "linear",
            LayerKind::Relu => "relu",
            LayerKind::Residual => "residual",
            LayerKind::Conv3x3 => "conv3x3",
            LayerKind::MaxPool2x2 => "maxpool",
            LayerKind::Flatten => "flatten",
        }
    }

    /// Kinds that carry an NCHW [`Spatial`] descriptor.
    pub fn is_spatial(&self) -> bool {
        matches!(
            self,
            LayerKind::Conv3x3 | LayerKind::MaxPool2x2 | LayerKind::Flatten
        )
    }
}

/// NCHW geometry of one spatial layer: the incoming image planes
/// (`c_in` × `h` × `w`) and the outgoing channel count. Output spatial dims
/// follow from the kind (conv3x3 preserves H×W, maxpool halves them,
/// flatten leaves the flat vector as-is).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Spatial {
    pub c_in: usize,
    pub h: usize,
    pub w: usize,
    pub c_out: usize,
}

/// Static shape of one layer: the dense `[B, d_in] → [B, d_out]` contract
/// every engine component sees, plus the NCHW descriptor for spatial kinds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LayerShape {
    pub kind: LayerKind,
    pub d_in: usize,
    pub d_out: usize,
    /// present iff `kind.is_spatial()`
    pub spatial: Option<Spatial>,
}

impl LayerShape {
    /// Dense constructor (linear/relu/residual). Spatial kinds need their
    /// NCHW geometry — use [`Self::conv3x3`] / [`Self::maxpool2`] /
    /// [`Self::flatten`].
    pub fn new(kind: LayerKind, d_in: usize, d_out: usize) -> Result<LayerShape> {
        if kind.is_spatial() {
            return Err(Error::Shape(format!(
                "{} layer needs NCHW dims; use the spatial constructors",
                kind.as_str()
            )));
        }
        if kind == LayerKind::Residual && d_in != d_out {
            return Err(Error::Shape(format!(
                "residual layer requires d_in == d_out, got {d_in} x {d_out}"
            )));
        }
        Ok(LayerShape { kind, d_in, d_out, spatial: None })
    }

    /// 3×3 stride-1 zero-pad conv (+ReLU) over `c_in`×`h`×`w` planes to
    /// `c_out` channels; H and W are preserved.
    pub fn conv3x3(c_in: usize, h: usize, w: usize, c_out: usize) -> Result<LayerShape> {
        if c_in == 0 || c_out == 0 || h == 0 || w == 0 {
            return Err(Error::Shape(format!(
                "conv3x3 dims must be nonzero, got {c_in}x{h}x{w} -> {c_out}"
            )));
        }
        Ok(LayerShape {
            kind: LayerKind::Conv3x3,
            d_in: c_in * h * w,
            d_out: c_out * h * w,
            spatial: Some(Spatial { c_in, h, w, c_out }),
        })
    }

    /// 2×2 stride-2 max pool over `c`×`h`×`w` planes (H, W must be even).
    pub fn maxpool2(c: usize, h: usize, w: usize) -> Result<LayerShape> {
        if c == 0 || h == 0 || w == 0 {
            return Err(Error::Shape(format!(
                "maxpool dims must be nonzero, got {c}x{h}x{w}"
            )));
        }
        if h % 2 != 0 || w % 2 != 0 {
            return Err(Error::Shape(format!(
                "maxpool needs even H and W, got {c}x{h}x{w}"
            )));
        }
        Ok(LayerShape {
            kind: LayerKind::MaxPool2x2,
            d_in: c * h * w,
            d_out: c * (h / 2) * (w / 2),
            spatial: Some(Spatial { c_in: c, h, w, c_out: c }),
        })
    }

    /// NCHW → dense boundary marker (identity on the flat buffer).
    pub fn flatten(c: usize, h: usize, w: usize) -> Result<LayerShape> {
        if c * h * w == 0 {
            return Err(Error::Shape(format!(
                "flatten dims must be nonzero, got {c}x{h}x{w}"
            )));
        }
        Ok(LayerShape {
            kind: LayerKind::Flatten,
            d_in: c * h * w,
            d_out: c * h * w,
            spatial: Some(Spatial { c_in: c, h, w, c_out: c }),
        })
    }

    /// Weight tensor shape `[rows, cols]`: dense layers store `[d_in,
    /// d_out]`, conv stores the im2col matrix `[9·c_in, c_out]`, and
    /// parameter-free layers a `[0, 0]` placeholder (so every layer keeps
    /// the uniform (W, b) slot the optimizer/gossip plumbing expects).
    pub fn w_shape(&self) -> [usize; 2] {
        match (self.kind, self.spatial) {
            (LayerKind::Conv3x3, Some(sp)) => [9 * sp.c_in, sp.c_out],
            (LayerKind::MaxPool2x2 | LayerKind::Flatten, _) => [0, 0],
            _ => [self.d_in, self.d_out],
        }
    }

    /// Bias length (0 for parameter-free layers).
    pub fn b_len(&self) -> usize {
        match (self.kind, self.spatial) {
            (LayerKind::Conv3x3, Some(sp)) => sp.c_out,
            (LayerKind::MaxPool2x2 | LayerKind::Flatten, _) => 0,
            _ => self.d_out,
        }
    }

    /// Flattened parameter count (W then b).
    pub fn param_count(&self) -> usize {
        let [r, c] = self.w_shape();
        r * c + self.b_len()
    }

    /// Artifact key (matches `LayerSpec.key` in python/compile/model.py for
    /// the dense kinds; spatial kinds append their NCHW geometry).
    pub fn key(&self, batch: usize) -> String {
        match (self.kind, self.spatial) {
            (_, Some(sp)) => format!(
                "{}_{batch}x{}x{}x{}x{}",
                self.kind.as_str(),
                sp.c_in,
                sp.h,
                sp.w,
                sp.c_out
            ),
            _ => format!("{}_{batch}x{}x{}", self.kind.as_str(), self.d_in, self.d_out),
        }
    }
}

/// Build the reference residual-MLP layer stack used by all experiments:
/// d_in -> hidden (relu) -> [hidden -> hidden residual] * blocks -> classes.
pub fn resmlp_layers(
    d_in: usize,
    hidden: usize,
    blocks: usize,
    classes: usize,
) -> Vec<LayerShape> {
    let mut layers = vec![LayerShape {
        kind: LayerKind::Relu,
        d_in,
        d_out: hidden,
        spatial: None,
    }];
    layers.extend((0..blocks).map(|_| LayerShape {
        kind: LayerKind::Residual,
        d_in: hidden,
        d_out: hidden,
        spatial: None,
    }));
    layers.push(LayerShape {
        kind: LayerKind::Linear,
        d_in: hidden,
        d_out: classes,
        spatial: None,
    });
    layers
}

/// Shape-inference cursor while growing a stack from layer specs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Cursor {
    /// NCHW planes (before `flatten`)
    Spatial { c: usize, h: usize, w: usize },
    /// flat feature width (after `flatten`, or a pure-dense stack's input)
    Flat(usize),
}

/// Parse the positive-integer parameter of a `name:N` layer spec.
fn spec_param(spec: &str, val: &str) -> Result<usize> {
    let n: usize = val
        .trim()
        .parse()
        .map_err(|_| Error::Config(format!("bad layer spec {spec:?}: want a positive integer")))?;
    if n == 0 {
        return Err(Error::Config(format!(
            "bad layer spec {spec:?}: parameter must be >= 1"
        )));
    }
    Ok(n)
}

/// Build a layer stack from the spec grammar, shape-inferring through an
/// NCHW input of `in_c`×`in_h`×`in_w` planes:
///
/// * `conv3x3:C` — 3×3/s1/p1 conv (+ReLU) to C channels (before `flatten`)
/// * `maxpool`   — 2×2/s2 max pool (before `flatten`; H, W must be even)
/// * `flatten`   — NCHW → dense boundary (required before any dense spec)
/// * `relu:D` / `linear:D` — dense layer to width D (after `flatten`)
/// * `residual`  — square residual dense block (after `flatten`)
///
/// Specs are trimmed and case-folded; every rejection is an
/// [`Error::Config`] carrying the offending spec string.
pub fn build_stack<S: AsRef<str>>(
    in_c: usize,
    in_h: usize,
    in_w: usize,
    specs: &[S],
) -> Result<Vec<LayerShape>> {
    if specs.is_empty() {
        return Err(Error::Config("layer spec list is empty".into()));
    }
    let mut cursor = Cursor::Spatial { c: in_c, h: in_h, w: in_w };
    let mut layers = Vec::with_capacity(specs.len());
    for raw in specs {
        let raw = raw.as_ref();
        let spec = raw.trim().to_ascii_lowercase();
        let (name, param) = match spec.split_once(':') {
            Some((n, p)) => (n.trim(), Some(p)),
            None => (spec.as_str(), None),
        };
        let need_spatial = |cursor: Cursor| match cursor {
            Cursor::Spatial { c, h, w } => Ok((c, h, w)),
            Cursor::Flat(_) => Err(Error::Config(format!(
                "layer spec {raw:?} needs NCHW input but follows \"flatten\""
            ))),
        };
        let need_flat = |cursor: Cursor| match cursor {
            Cursor::Flat(d) => Ok(d),
            Cursor::Spatial { .. } => Err(Error::Config(format!(
                "dense layer spec {raw:?} before \"flatten\""
            ))),
        };
        let layer = match (name, param) {
            ("conv3x3", Some(p)) => {
                let (c, h, w) = need_spatial(cursor)?;
                let c_out = spec_param(raw, p)?;
                cursor = Cursor::Spatial { c: c_out, h, w };
                LayerShape::conv3x3(c, h, w, c_out)
                    .map_err(|e| Error::Config(format!("layer spec {raw:?}: {e}")))?
            }
            ("maxpool", None) => {
                let (c, h, w) = need_spatial(cursor)?;
                let l = LayerShape::maxpool2(c, h, w)
                    .map_err(|e| Error::Config(format!("layer spec {raw:?}: {e}")))?;
                cursor = Cursor::Spatial { c, h: h / 2, w: w / 2 };
                l
            }
            ("flatten", None) => {
                let (c, h, w) = need_spatial(cursor)?;
                cursor = Cursor::Flat(c * h * w);
                LayerShape::flatten(c, h, w)
                    .map_err(|e| Error::Config(format!("layer spec {raw:?}: {e}")))?
            }
            ("relu", Some(p)) | ("linear", Some(p)) => {
                let d = need_flat(cursor)?;
                let d_out = spec_param(raw, p)?;
                let kind = if name == "relu" { LayerKind::Relu } else { LayerKind::Linear };
                cursor = Cursor::Flat(d_out);
                LayerShape::new(kind, d, d_out)?
            }
            ("residual", None) => {
                let d = need_flat(cursor)?;
                LayerShape::new(LayerKind::Residual, d, d)?
            }
            _ => {
                return Err(Error::Config(format!(
                    "unknown layer spec {raw:?} \
                     (want conv3x3:C|maxpool|flatten|relu:D|linear:D|residual)"
                )))
            }
        };
        layers.push(layer);
    }
    if let Cursor::Spatial { .. } = cursor {
        return Err(Error::Config(
            "layer stack never reaches \"flatten\": the loss head needs a dense output".into(),
        ));
    }
    Ok(layers)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for k in [
            LayerKind::Linear,
            LayerKind::Relu,
            LayerKind::Residual,
            LayerKind::Conv3x3,
            LayerKind::MaxPool2x2,
            LayerKind::Flatten,
        ] {
            assert_eq!(LayerKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(LayerKind::parse("conv").is_err());
    }

    #[test]
    fn parse_trims_and_case_folds_with_config_error() {
        assert_eq!(LayerKind::parse(" Conv3x3 ").unwrap(), LayerKind::Conv3x3);
        assert_eq!(LayerKind::parse("RELU").unwrap(), LayerKind::Relu);
        let err = LayerKind::parse("warp").unwrap_err();
        assert!(matches!(err, Error::Config(_)), "{err:?}");
        assert!(err.to_string().contains("warp"), "{err}");
    }

    #[test]
    fn residual_must_be_square() {
        assert!(LayerShape::new(LayerKind::Residual, 4, 5).is_err());
        assert!(LayerShape::new(LayerKind::Residual, 4, 4).is_ok());
        assert!(LayerShape::new(LayerKind::Relu, 4, 5).is_ok());
    }

    #[test]
    fn spatial_kinds_reject_dense_constructor() {
        for k in [LayerKind::Conv3x3, LayerKind::MaxPool2x2, LayerKind::Flatten] {
            assert!(LayerShape::new(k, 4, 4).is_err(), "{k:?}");
        }
    }

    #[test]
    fn key_matches_python_format() {
        let l = LayerShape::new(LayerKind::Relu, 256, 128).unwrap();
        assert_eq!(l.key(194), "relu_194x256x128");
        let c = LayerShape::conv3x3(3, 32, 32, 16).unwrap();
        assert_eq!(c.key(8), "conv3x3_8x3x32x32x16");
    }

    #[test]
    fn resmlp_structure() {
        let layers = resmlp_layers(32, 16, 3, 10);
        assert_eq!(layers.len(), 5);
        assert_eq!(layers[0].kind, LayerKind::Relu);
        assert!(layers[1..4].iter().all(|l| l.kind == LayerKind::Residual));
        assert_eq!(layers[4].kind, LayerKind::Linear);
        assert_eq!(layers[4].d_out, 10);
    }

    #[test]
    fn param_count() {
        let l = LayerShape::new(LayerKind::Relu, 3, 2).unwrap();
        assert_eq!(l.param_count(), 8);
        let c = LayerShape::conv3x3(3, 8, 8, 4).unwrap();
        assert_eq!(c.param_count(), 9 * 3 * 4 + 4);
        assert_eq!(LayerShape::maxpool2(4, 8, 8).unwrap().param_count(), 0);
        assert_eq!(LayerShape::flatten(4, 4, 4).unwrap().param_count(), 0);
    }

    #[test]
    fn conv_shapes_flatten_nchw() {
        let c = LayerShape::conv3x3(3, 32, 32, 16).unwrap();
        assert_eq!((c.d_in, c.d_out), (3 * 1024, 16 * 1024));
        assert_eq!(c.w_shape(), [27, 16]);
        assert_eq!(c.b_len(), 16);
        let p = LayerShape::maxpool2(16, 32, 32).unwrap();
        assert_eq!((p.d_in, p.d_out), (16 * 1024, 16 * 256));
        assert_eq!(p.w_shape(), [0, 0]);
        assert!(LayerShape::maxpool2(16, 7, 8).is_err(), "odd H rejected");
    }

    #[test]
    fn build_stack_infers_cifar_cnn_shapes() {
        let layers = build_stack(
            3,
            32,
            32,
            &["conv3x3:8", "maxpool", "conv3x3:16", "maxpool", "flatten", "relu:64", "linear:10"],
        )
        .unwrap();
        assert_eq!(layers.len(), 7);
        assert_eq!(layers[0].d_in, 3072);
        assert_eq!(layers[2].spatial.unwrap().c_in, 8);
        assert_eq!(layers[2].spatial.unwrap().h, 16);
        assert_eq!(layers[4].kind, LayerKind::Flatten);
        assert_eq!(layers[4].d_out, 16 * 8 * 8);
        assert_eq!(layers[5].d_in, 1024);
        assert_eq!(layers[6].d_out, 10);
        // chain is consistent
        for pair in layers.windows(2) {
            assert_eq!(pair[0].d_out, pair[1].d_in);
        }
    }

    #[test]
    fn build_stack_specs_are_trimmed_and_case_folded() {
        let layers = build_stack(2, 4, 4, &[" Conv3x3:3 ", "FLATTEN", "Linear:5"]).unwrap();
        assert_eq!(layers[0].kind, LayerKind::Conv3x3);
        assert_eq!(layers[2].d_out, 5);
    }

    #[test]
    fn build_stack_rejects_bad_specs_with_the_offending_string() {
        for (in_dims, bad, why) in [
            ((3usize, 8usize, 8usize), vec!["conv4x4:8", "flatten"], "unknown"),
            ((3, 8, 8), vec!["conv3x3:0", "flatten"], ">= 1"),
            ((3, 8, 8), vec!["conv3x3:x", "flatten"], "integer"),
            ((3, 8, 8), vec!["relu:8"], "before \"flatten\""),
            ((3, 8, 8), vec!["flatten", "conv3x3:4"], "follows \"flatten\""),
            ((3, 8, 8), vec!["conv3x3:4"], "never reaches"),
            ((3, 7, 8), vec!["maxpool", "flatten"], "even"),
        ] {
            let (c, h, w) = in_dims;
            let err = build_stack(c, h, w, &bad).unwrap_err();
            assert!(matches!(err, Error::Config(_)), "{bad:?}: {err:?}");
            assert!(err.to_string().contains(why), "{bad:?}: {err}");
        }
    }
}
