//! Layer vocabulary shared with the compile path.
//!
//! `LayerKind` string forms must stay in sync with
//! `python/compile/kernels/ref.py` (KIND_*) and the manifest emitted by
//! `python/compile/aot.py`.

use crate::error::{Error, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerKind {
    /// z = x·W + b
    Linear,
    /// relu(z)
    Relu,
    /// relu(z) + x  (requires d_in == d_out)
    Residual,
}

impl LayerKind {
    pub fn parse(s: &str) -> Result<LayerKind> {
        match s {
            "linear" => Ok(LayerKind::Linear),
            "relu" => Ok(LayerKind::Relu),
            "residual" => Ok(LayerKind::Residual),
            _ => Err(Error::Manifest(format!("unknown layer kind {s:?}"))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            LayerKind::Linear => "linear",
            LayerKind::Relu => "relu",
            LayerKind::Residual => "residual",
        }
    }
}

/// Static shape of one dense layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LayerShape {
    pub kind: LayerKind,
    pub d_in: usize,
    pub d_out: usize,
}

impl LayerShape {
    pub fn new(kind: LayerKind, d_in: usize, d_out: usize) -> Result<LayerShape> {
        if kind == LayerKind::Residual && d_in != d_out {
            return Err(Error::Shape(format!(
                "residual layer requires d_in == d_out, got {d_in} x {d_out}"
            )));
        }
        Ok(LayerShape { kind, d_in, d_out })
    }

    /// Flattened parameter count (W then b).
    pub fn param_count(&self) -> usize {
        self.d_in * self.d_out + self.d_out
    }

    /// Artifact key (matches `LayerSpec.key` in python/compile/model.py).
    pub fn key(&self, batch: usize) -> String {
        format!("{}_{batch}x{}x{}", self.kind.as_str(), self.d_in, self.d_out)
    }
}

/// Build the reference residual-MLP layer stack used by all experiments:
/// d_in -> hidden (relu) -> [hidden -> hidden residual] * blocks -> classes.
pub fn resmlp_layers(
    d_in: usize,
    hidden: usize,
    blocks: usize,
    classes: usize,
) -> Vec<LayerShape> {
    let mut layers = vec![LayerShape {
        kind: LayerKind::Relu,
        d_in,
        d_out: hidden,
    }];
    layers.extend((0..blocks).map(|_| LayerShape {
        kind: LayerKind::Residual,
        d_in: hidden,
        d_out: hidden,
    }));
    layers.push(LayerShape {
        kind: LayerKind::Linear,
        d_in: hidden,
        d_out: classes,
    });
    layers
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for k in [LayerKind::Linear, LayerKind::Relu, LayerKind::Residual] {
            assert_eq!(LayerKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(LayerKind::parse("conv").is_err());
    }

    #[test]
    fn residual_must_be_square() {
        assert!(LayerShape::new(LayerKind::Residual, 4, 5).is_err());
        assert!(LayerShape::new(LayerKind::Residual, 4, 4).is_ok());
        assert!(LayerShape::new(LayerKind::Relu, 4, 5).is_ok());
    }

    #[test]
    fn key_matches_python_format() {
        let l = LayerShape::new(LayerKind::Relu, 256, 128).unwrap();
        assert_eq!(l.key(194), "relu_194x256x128");
    }

    #[test]
    fn resmlp_structure() {
        let layers = resmlp_layers(32, 16, 3, 10);
        assert_eq!(layers.len(), 5);
        assert_eq!(layers[0].kind, LayerKind::Relu);
        assert!(layers[1..4].iter().all(|l| l.kind == LayerKind::Residual));
        assert_eq!(layers[4].kind, LayerKind::Linear);
        assert_eq!(layers[4].d_out, 10);
    }

    #[test]
    fn param_count() {
        let l = LayerShape::new(LayerKind::Relu, 3, 2).unwrap();
        assert_eq!(l.param_count(), 8);
    }
}
