//! Pure-Rust neural-network math: the native `ComputeBackend`.
//!
//! Implements exactly the same per-layer forward/backward and loss-grad
//! contracts as the Pallas kernels (python/compile/kernels), so it serves
//! three roles:
//!   1. the finite-difference-checked **oracle** the XLA path is validated
//!      against (tests/integration_backends.rs),
//!   2. an artifact-free fallback backend (coordinator runs without
//!      `make artifacts`),
//!   3. the "traditional BP on one device" baseline comparator.
//!
//! Matmuls use an ikj loop ordering (row-major friendly, autovectorizes);
//! blocking is deliberately left to the XLA path — see DESIGN.md §Perf.

pub mod grad_check;
pub mod init;
pub mod layer;

pub use layer::{resmlp_layers, LayerKind, LayerShape};

use crate::tensor::Tensor;

/// out[m,n] += a[m,k] @ b[k,n]
fn matmul_acc(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k_dim: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k_dim);
    debug_assert_eq!(b.len(), k_dim * n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let a_row = &a[i * k_dim..(i + 1) * k_dim];
        let o_row = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in a_row.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let b_row = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in o_row.iter_mut().zip(b_row) {
                *o += av * bv;
            }
        }
    }
}

/// out[m,n] = a[m,k] @ b[n,k]^T
///
/// §Perf: the naive per-(i,j) dot-product version ran ~2.5x slower per
/// FLOP than `matmul_acc` (serial accumulator chains defeat
/// autovectorization). Restructured as 4-row blocks of dot products so
/// the compiler keeps 4 independent accumulator vectors in flight;
/// see EXPERIMENTS.md §Perf for the before/after.
fn matmul_nt(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k_dim: usize, n: usize) {
    debug_assert_eq!(b.len(), n * k_dim);
    for i in 0..m {
        let a_row = &a[i * k_dim..(i + 1) * k_dim];
        let o_row = &mut out[i * n..(i + 1) * n];
        let mut j = 0;
        // 4 output columns at a time: 4 independent accumulators
        while j + 4 <= n {
            let b0 = &b[j * k_dim..(j + 1) * k_dim];
            let b1 = &b[(j + 1) * k_dim..(j + 2) * k_dim];
            let b2 = &b[(j + 2) * k_dim..(j + 3) * k_dim];
            let b3 = &b[(j + 3) * k_dim..(j + 4) * k_dim];
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for kk in 0..k_dim {
                let av = a_row[kk];
                s0 += av * b0[kk];
                s1 += av * b1[kk];
                s2 += av * b2[kk];
                s3 += av * b3[kk];
            }
            o_row[j] = s0;
            o_row[j + 1] = s1;
            o_row[j + 2] = s2;
            o_row[j + 3] = s3;
            j += 4;
        }
        while j < n {
            let b_row = &b[j * k_dim..(j + 1) * k_dim];
            o_row[j] = a_row.iter().zip(b_row).map(|(x, y)| x * y).sum();
            j += 1;
        }
    }
}

/// out[m,n] = a[k,m]^T @ b[k,n]
///
/// §Perf note: the `av == 0.0` skip stays — `a` here is the stashed input
/// activation (post-ReLU, a large zero fraction in hidden layers); removing
/// the branch was tried and regressed residual-layer bwd ~15%
/// (EXPERIMENTS.md §Perf, iteration 2).
fn matmul_tn(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k_dim: usize, n: usize) {
    debug_assert_eq!(a.len(), k_dim * m);
    out.iter_mut().for_each(|o| *o = 0.0);
    for kk in 0..k_dim {
        let a_row = &a[kk * m..(kk + 1) * m];
        let b_row = &b[kk * n..(kk + 1) * n];
        for (i, &av) in a_row.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let o_row = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in o_row.iter_mut().zip(b_row) {
                *o += av * bv;
            }
        }
    }
}

/// Forward one dense layer: h_out = act(x·W + b) [+ x].
///
/// x: [B, d_in], w: [d_in, d_out] (row-major), b: [d_out].
pub fn dense_fwd(x: &Tensor, w: &Tensor, b: &Tensor, kind: LayerKind) -> Tensor {
    let (batch, d_in) = (x.shape()[0], x.shape()[1]);
    let d_out = w.shape()[1];
    debug_assert_eq!(w.shape()[0], d_in);
    debug_assert_eq!(b.len(), d_out);
    let mut out = Tensor::zeros(&[batch, d_out]);
    matmul_acc(x.data(), w.data(), out.data_mut(), batch, d_in, d_out);
    let od = out.data_mut();
    for i in 0..batch {
        for j in 0..d_out {
            let mut z = od[i * d_out + j] + b.data()[j];
            match kind {
                LayerKind::Linear => {}
                LayerKind::Relu => z = z.max(0.0),
                LayerKind::Residual => z = z.max(0.0) + x.data()[i * d_out + j],
            }
            od[i * d_out + j] = z;
        }
    }
    out
}

/// Backward one dense layer; mirrors `ref.dense_bwd_ref`.
///
/// Returns (g_x, g_w, g_b). `h_out` must be the forward output computed
/// with exactly these `x` and `w` (the staleness buffers guarantee it).
pub fn dense_bwd(
    x: &Tensor,
    w: &Tensor,
    h_out: &Tensor,
    g_out: &Tensor,
    kind: LayerKind,
) -> (Tensor, Tensor, Tensor) {
    let (batch, d_in) = (x.shape()[0], x.shape()[1]);
    let d_out = w.shape()[1];

    // g_z = g_out * mask(z > 0), mask reconstructed from stored outputs
    let mut g_z = g_out.clone();
    match kind {
        LayerKind::Linear => {}
        LayerKind::Relu => {
            for (g, &h) in g_z.data_mut().iter_mut().zip(h_out.data()) {
                if h <= 0.0 {
                    *g = 0.0;
                }
            }
        }
        LayerKind::Residual => {
            for ((g, &h), &xv) in g_z
                .data_mut()
                .iter_mut()
                .zip(h_out.data())
                .zip(x.data())
            {
                if h - xv <= 0.0 {
                    *g = 0.0;
                }
            }
        }
    }

    let mut g_x = Tensor::zeros(&[batch, d_in]);
    matmul_nt(g_z.data(), w.data(), g_x.data_mut(), batch, d_out, d_in);
    if kind == LayerKind::Residual {
        g_x.axpy(1.0, g_out);
    }

    let mut g_w = Tensor::zeros(&[d_in, d_out]);
    matmul_tn(x.data(), g_z.data(), g_w.data_mut(), d_in, batch, d_out);

    let mut g_b = Tensor::zeros(&[d_out]);
    for i in 0..batch {
        for j in 0..d_out {
            g_b.data_mut()[j] += g_z.data()[i * d_out + j];
        }
    }
    (g_x, g_w, g_b)
}

/// Fused softmax cross-entropy: (mean_loss, g_logits) with the 1/B mean
/// baked into the gradient (eq. (4)).
pub fn softmax_xent(logits: &Tensor, onehot: &Tensor) -> (f32, Tensor) {
    let (batch, classes) = (logits.shape()[0], logits.shape()[1]);
    debug_assert_eq!(onehot.shape(), logits.shape());
    let inv_b = 1.0 / batch as f32;
    let mut g = Tensor::zeros(&[batch, classes]);
    let mut loss = 0.0f64;
    for i in 0..batch {
        let row = &logits.data()[i * classes..(i + 1) * classes];
        let oh = &onehot.data()[i * classes..(i + 1) * classes];
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0f32;
        for &v in row {
            sum += (v - m).exp();
        }
        let lse = sum.ln();
        let g_row = &mut g.data_mut()[i * classes..(i + 1) * classes];
        for j in 0..classes {
            let logp = row[j] - m - lse;
            loss -= (oh[j] * logp) as f64;
            g_row[j] = ((row[j] - m).exp() / sum - oh[j]) * inv_b;
        }
    }
    ((loss * inv_b as f64) as f32, g)
}

/// Full-network forward over a layer stack; params are (W, b) pairs.
pub fn full_forward(x: &Tensor, params: &[(Tensor, Tensor)], layers: &[LayerShape]) -> Tensor {
    let mut h = x.clone();
    for ((w, b), layer) in params.iter().zip(layers) {
        h = dense_fwd(&h, w, b, layer.kind);
    }
    h
}

/// Mean loss of the whole network on (x, onehot).
pub fn full_loss(
    x: &Tensor,
    onehot: &Tensor,
    params: &[(Tensor, Tensor)],
    layers: &[LayerShape],
) -> f32 {
    let logits = full_forward(x, params, layers);
    softmax_xent(&logits, onehot).0
}

/// Whole-network gradient via per-layer backward chaining: the exact
/// computation the coordinator distributes across K modules, in one place.
/// Returns mean-scaled (g_w, g_b) per layer.
pub fn full_backward(
    x: &Tensor,
    onehot: &Tensor,
    params: &[(Tensor, Tensor)],
    layers: &[LayerShape],
) -> (f32, Vec<(Tensor, Tensor)>) {
    // forward, stashing every activation (same as the staleness buffers)
    let mut acts = vec![x.clone()];
    for ((w, b), layer) in params.iter().zip(layers) {
        let h = dense_fwd(acts.last().unwrap(), w, b, layer.kind);
        acts.push(h);
    }
    let (loss, mut g) = softmax_xent(acts.last().unwrap(), onehot);
    let mut grads = Vec::with_capacity(params.len());
    for i in (0..params.len()).rev() {
        let (w, _) = &params[i];
        let (g_x, g_w, g_b) = dense_bwd(&acts[i], w, &acts[i + 1], &g, layers[i].kind);
        grads.push((g_w, g_b));
        g = g_x;
    }
    grads.reverse();
    (loss, grads)
}

/// Classification accuracy of logits vs one-hot labels.
pub fn accuracy(logits: &Tensor, onehot: &Tensor) -> f64 {
    let (batch, classes) = (logits.shape()[0], logits.shape()[1]);
    let mut correct = 0usize;
    for i in 0..batch {
        let row = &logits.data()[i * classes..(i + 1) * classes];
        let oh = &onehot.data()[i * classes..(i + 1) * classes];
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let label = oh
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred == label {
            correct += 1;
        }
    }
    correct as f64 / batch as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::init::he_init;
    use crate::util::rng::Pcg32;

    fn rand_tensor(rng: &mut Pcg32, shape: &[usize]) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(t.data_mut(), 1.0);
        t
    }

    #[test]
    fn dense_fwd_known_values() {
        // x = [[1, 2]], W = [[1, 0], [0, 1]], b = [0.5, -10]
        let x = Tensor::from_vec(&[1, 2], vec![1.0, 2.0]).unwrap();
        let w = Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        let b = Tensor::from_vec(&[2], vec![0.5, -10.0]).unwrap();
        let lin = dense_fwd(&x, &w, &b, LayerKind::Linear);
        assert_eq!(lin.data(), &[1.5, -8.0]);
        let relu = dense_fwd(&x, &w, &b, LayerKind::Relu);
        assert_eq!(relu.data(), &[1.5, 0.0]);
        let res = dense_fwd(&x, &w, &b, LayerKind::Residual);
        assert_eq!(res.data(), &[2.5, 2.0]);
    }

    #[test]
    fn softmax_xent_uniform_is_log_c() {
        let logits = Tensor::zeros(&[4, 10]);
        let mut onehot = Tensor::zeros(&[4, 10]);
        for i in 0..4 {
            onehot.data_mut()[i * 10 + i] = 1.0;
        }
        let (loss, g) = softmax_xent(&logits, &onehot);
        assert!((loss - (10.0f32).ln()).abs() < 1e-5);
        // gradient rows sum to zero
        for i in 0..4 {
            let s: f32 = g.data()[i * 10..(i + 1) * 10].iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_stable_with_large_logits() {
        let logits = Tensor::from_vec(&[1, 2], vec![1000.0, -1000.0]).unwrap();
        let onehot = Tensor::from_vec(&[1, 2], vec![1.0, 0.0]).unwrap();
        let (loss, g) = softmax_xent(&logits, &onehot);
        assert!(loss.is_finite() && loss < 1e-3);
        assert!(g.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn bwd_matches_finite_difference_all_kinds() {
        let mut rng = Pcg32::new(1);
        for kind in [LayerKind::Linear, LayerKind::Relu, LayerKind::Residual] {
            let (b_sz, d) = (4, 6);
            let x = rand_tensor(&mut rng, &[b_sz, d]);
            let w = he_init(&mut rng, d, d);
            let bias = rand_tensor(&mut rng, &[d]);
            let layer = LayerShape::new(kind, d, d).unwrap();
            let err = grad_check::check_layer(&x, &w, &bias, layer, 1e-3, &mut rng);
            assert!(err < 2e-2, "{kind:?}: fd mismatch {err}");
        }
    }

    #[test]
    fn full_backward_matches_finite_difference() {
        let mut rng = Pcg32::new(2);
        let layers = resmlp_layers(8, 6, 2, 4);
        let params: Vec<(Tensor, Tensor)> = layers
            .iter()
            .map(|l| (he_init(&mut rng, l.d_in, l.d_out), Tensor::zeros(&[l.d_out])))
            .collect();
        let x = rand_tensor(&mut rng, &[5, 8]);
        let mut onehot = Tensor::zeros(&[5, 4]);
        for i in 0..5 {
            let c = rng.below(4);
            onehot.data_mut()[i * 4 + c] = 1.0;
        }
        let err = grad_check::check_full(&x, &onehot, &params, &layers, 1e-3, &mut rng);
        assert!(err < 2e-2, "fd mismatch {err}");
    }

    #[test]
    fn accuracy_basics() {
        let logits = Tensor::from_vec(&[2, 3], vec![1.0, 5.0, 0.0, 9.0, 1.0, 1.0]).unwrap();
        let onehot = Tensor::from_vec(&[2, 3], vec![0.0, 1.0, 0.0, 0.0, 0.0, 1.0]).unwrap();
        assert_eq!(accuracy(&logits, &onehot), 0.5);
    }

    #[test]
    fn matmul_variants_agree_with_naive() {
        let mut rng = Pcg32::new(5);
        let (m, k, n) = (7, 5, 6);
        let a = rand_tensor(&mut rng, &[m, k]);
        let bt = rand_tensor(&mut rng, &[n, k]);
        let at = rand_tensor(&mut rng, &[k, m]);
        let b = rand_tensor(&mut rng, &[k, n]);

        // nt: a @ bt^T
        let mut out = vec![0.0; m * n];
        matmul_nt(a.data(), bt.data(), &mut out, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let want: f32 = (0..k).map(|kk| a.data()[i * k + kk] * bt.data()[j * k + kk]).sum();
                assert!((out[i * n + j] - want).abs() < 1e-4);
            }
        }
        // tn: at^T @ b
        let mut out2 = vec![0.0; m * n];
        matmul_tn(at.data(), b.data(), &mut out2, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let want: f32 = (0..k).map(|kk| at.data()[kk * m + i] * b.data()[kk * n + j]).sum();
                assert!((out2[i * n + j] - want).abs() < 1e-4);
            }
        }
    }
}
