//! Pure-Rust neural-network math: the native `ComputeBackend`.
//!
//! Implements exactly the same per-layer forward/backward and loss-grad
//! contracts as the Pallas kernels (python/compile/kernels), so it serves
//! three roles:
//!   1. the finite-difference-checked **oracle** the XLA path is validated
//!      against (tests/integration_backends.rs),
//!   2. an artifact-free fallback backend (coordinator runs without
//!      `make artifacts`),
//!   3. the "traditional BP on one device" baseline comparator.
//!
//! §Perf — every kernel is an **in-place, caller-owned-workspace** variant
//! (`dense_fwd_into` / `dense_bwd_into` / `softmax_xent_into`, plus the
//! [`conv`] family dispatched through `layer_fwd_into` / `layer_bwd_into`):
//! the steady-state training loop allocates nothing (tests/alloc_guard.rs).
//! The matmuls are k-blocked (`KBLOCK`-row panels of `b` stay hot in
//! L1/L2 while the output rows stream past) and parallelized over fixed
//! output-row chunks with `std::thread::scope` — each output element is
//! always accumulated in ascending-k order by exactly one worker, so a
//! single-threaded run is bit-identical to any worker count (the engines'
//! equivalence tests keep pinning semantics). The backward input-gradient
//! matmul transposes W once into workspace scratch and runs in saxpy form
//! (`g_x += g_z[i,k] * w_t[k,:]`): serial dot-product accumulator chains
//! defeated autovectorization in the old `matmul_nt`, and the ReLU-masked
//! `g_z` rows make the zero-skip branch pay twice over.

pub mod conv;
pub mod grad_check;
pub mod init;
pub mod layer;

pub use conv::FwdScratch;
pub use layer::{build_stack, resmlp_layers, LayerKind, LayerShape, Spatial};

use crate::tensor::Tensor;

/// Resolved worker count for the native kernels and the group-parallel
/// engine step: `requested` workers, with 0 meaning the machine's
/// available parallelism (the `--compute-threads` default).
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }
}

/// Minimum multiply-accumulates each extra worker must bring before a
/// kernel fans out: below this, `std::thread::scope` spawn/join overhead
/// (~tens of µs) outweighs the split and the kernel stays on the calling
/// thread. Chunk boundaries are fixed by (rows, workers) alone, never by
/// load, so the split is deterministic.
const MIN_MACS_PER_THREAD: usize = 1 << 19;

/// k-panel height for the blocked matmuls: a KBLOCK×n panel of `b`
/// (≤ 32 KiB at n = 128) stays resident while a chunk's output rows
/// stream past it.
const KBLOCK: usize = 64;

/// Workers to actually use for a kernel of `macs` multiply-accumulates
/// over `rows` independent output rows.
fn plan_threads(threads: usize, rows: usize, macs: usize) -> usize {
    if threads <= 1 || rows < 2 {
        return 1;
    }
    threads.min(rows).min((macs / MIN_MACS_PER_THREAD).max(1))
}

/// out[m,n] += a[m,k] @ b[k,n], k-blocked, parallel over fixed row chunks.
///
/// §Perf: the `av == 0.0` skip stays — `a` is a post-ReLU activation on
/// the forward path and the ReLU-masked `g_z` on the backward path, both
/// with a large zero fraction (EXPERIMENTS.md §Perf).
#[allow(clippy::too_many_arguments)]
fn matmul_acc(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k_dim: usize,
    n: usize,
    threads: usize,
) {
    debug_assert_eq!(a.len(), m * k_dim);
    debug_assert_eq!(b.len(), k_dim * n);
    debug_assert_eq!(out.len(), m * n);
    let nt = plan_threads(threads, m, m * k_dim * n);
    if nt <= 1 {
        matmul_acc_chunk(a, b, out, 0, k_dim, n);
        return;
    }
    let chunk = m.div_ceil(nt);
    std::thread::scope(|scope| {
        for (ci, out_chunk) in out.chunks_mut(chunk * n).enumerate() {
            scope.spawn(move || matmul_acc_chunk(a, b, out_chunk, ci * chunk, k_dim, n));
        }
    });
}

/// One row-chunk of `matmul_acc`: rows [row0, row0 + out.len()/n) of the
/// result. Accumulation is ascending-k per element regardless of chunking
/// or blocking — the determinism contract.
#[allow(clippy::needless_range_loop)]
fn matmul_acc_chunk(a: &[f32], b: &[f32], out: &mut [f32], row0: usize, k_dim: usize, n: usize) {
    let rows = out.len() / n;
    let mut kb = 0;
    while kb < k_dim {
        let ke = (kb + KBLOCK).min(k_dim);
        for i in 0..rows {
            let a_row = &a[(row0 + i) * k_dim..(row0 + i + 1) * k_dim];
            let o_row = &mut out[i * n..(i + 1) * n];
            for kk in kb..ke {
                let av = a_row[kk];
                if av == 0.0 {
                    continue;
                }
                let b_row = &b[kk * n..(kk + 1) * n];
                for (o, &bv) in o_row.iter_mut().zip(b_row) {
                    *o += av * bv;
                }
            }
        }
        kb = ke;
    }
}

/// out[m,n] = a[k,m]^T @ b[k,n], parallel over fixed output-row chunks.
///
/// §Perf note: the `av == 0.0` skip stays — `a` here is the stashed input
/// activation (post-ReLU, a large zero fraction in hidden layers); removing
/// the branch was tried and regressed residual-layer bwd ~15%
/// (EXPERIMENTS.md §Perf, iteration 2).
#[allow(clippy::too_many_arguments)]
fn matmul_tn(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k_dim: usize,
    n: usize,
    threads: usize,
) {
    debug_assert_eq!(a.len(), k_dim * m);
    debug_assert_eq!(b.len(), k_dim * n);
    debug_assert_eq!(out.len(), m * n);
    let nt = plan_threads(threads, m, m * k_dim * n);
    if nt <= 1 {
        matmul_tn_chunk(a, b, out, 0, m, k_dim, n);
        return;
    }
    let chunk = m.div_ceil(nt);
    std::thread::scope(|scope| {
        for (ci, out_chunk) in out.chunks_mut(chunk * n).enumerate() {
            scope.spawn(move || matmul_tn_chunk(a, b, out_chunk, ci * chunk, m, k_dim, n));
        }
    });
}

/// One row-chunk of `matmul_tn`: rows [col0, col0 + out.len()/n) of the
/// result (columns of `a`). Each worker reads all of `b` but writes a
/// disjoint row range, accumulating ascending-k — deterministic under any
/// chunking.
#[allow(clippy::too_many_arguments)]
fn matmul_tn_chunk(
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    col0: usize,
    m: usize,
    k_dim: usize,
    n: usize,
) {
    out.iter_mut().for_each(|o| *o = 0.0);
    let rows = out.len() / n;
    for kk in 0..k_dim {
        let a_row = &a[kk * m..(kk + 1) * m];
        let b_row = &b[kk * n..(kk + 1) * n];
        for i in 0..rows {
            let av = a_row[col0 + i];
            if av == 0.0 {
                continue;
            }
            let o_row = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in o_row.iter_mut().zip(b_row) {
                *o += av * bv;
            }
        }
    }
}

/// dst[cols, rows] = src[rows, cols]^T (row-major both sides).
fn transpose_into(src: &[f32], dst: &mut [f32], rows: usize, cols: usize) {
    debug_assert_eq!(src.len(), rows * cols);
    debug_assert_eq!(dst.len(), rows * cols);
    for r in 0..rows {
        let s_row = &src[r * cols..(r + 1) * cols];
        for (c, &v) in s_row.iter().enumerate() {
            dst[c * rows + r] = v;
        }
    }
}

/// Caller-owned scratch for one layer's backward pass: the masked output
/// gradient and the transposed weight panel, plus the conv path's im2col
/// buffers. Sized lazily on first use ([`Tensor::ensure_shape`]),
/// allocation-free after that; dense layers leave the conv buffers empty.
#[derive(Debug, Clone, Default)]
pub struct BwdScratch {
    /// g_z = g_out ⊙ mask(z > 0), [batch, d_out]
    pub g_z: Tensor,
    /// W^T, [d_out, d_in] — lets the g_x matmul run in saxpy form
    pub w_t: Tensor,
    /// conv: im2col of the stashed input, [B·H·W, 9·c_in]
    pub col: Tensor,
    /// conv: masked gradient in matmul layout, [B·H·W, c_out]
    pub g_tmp: Tensor,
    /// conv: gradient w.r.t. the column matrix, [B·H·W, 9·c_in]
    pub g_col: Tensor,
}

impl BwdScratch {
    pub fn new() -> BwdScratch {
        BwdScratch {
            g_z: Tensor::empty(),
            w_t: Tensor::empty(),
            col: Tensor::empty(),
            g_tmp: Tensor::empty(),
            g_col: Tensor::empty(),
        }
    }
}

/// Forward one dense layer into `out`: out = act(x·W + b) [+ x].
///
/// x: [B, d_in], w: [d_in, d_out] (row-major), b: [d_out]. `out` is sized
/// to [B, d_out] on first use and reused allocation-free afterwards.
pub fn dense_fwd_into(
    x: &Tensor,
    w: &Tensor,
    b: &Tensor,
    kind: LayerKind,
    out: &mut Tensor,
    threads: usize,
) {
    let (batch, d_in) = (x.shape()[0], x.shape()[1]);
    let d_out = w.shape()[1];
    debug_assert_eq!(w.shape()[0], d_in);
    debug_assert_eq!(b.len(), d_out);
    out.ensure_shape(&[batch, d_out]);
    out.fill_zero();
    matmul_acc(x.data(), w.data(), out.data_mut(), batch, d_in, d_out, threads);
    let od = out.data_mut();
    let (bd, xd) = (b.data(), x.data());
    for i in 0..batch {
        for j in 0..d_out {
            let mut z = od[i * d_out + j] + bd[j];
            match kind {
                LayerKind::Linear => {}
                LayerKind::Relu => z = z.max(0.0),
                LayerKind::Residual => z = z.max(0.0) + xd[i * d_out + j],
            }
            od[i * d_out + j] = z;
        }
    }
}

/// Backward one dense layer into caller-owned buffers; mirrors
/// `ref.dense_bwd_ref`.
///
/// `h_out` must be the forward output computed with exactly these `x` and
/// `w` (the staleness buffers guarantee it). Writes (g_x, g_w, g_b); all
/// out-buffers and `scratch` are sized on first use and reused
/// allocation-free afterwards.
#[allow(clippy::too_many_arguments)]
pub fn dense_bwd_into(
    x: &Tensor,
    w: &Tensor,
    h_out: &Tensor,
    g_out: &Tensor,
    kind: LayerKind,
    g_x: &mut Tensor,
    g_w: &mut Tensor,
    g_b: &mut Tensor,
    scratch: &mut BwdScratch,
    threads: usize,
) {
    let (batch, d_in) = (x.shape()[0], x.shape()[1]);
    let d_out = w.shape()[1];
    debug_assert_eq!(h_out.shape(), &[batch, d_out]);
    debug_assert_eq!(g_out.shape(), &[batch, d_out]);

    // g_z = g_out * mask(z > 0), mask reconstructed from stored outputs
    scratch.g_z.ensure_shape(&[batch, d_out]);
    let gz = scratch.g_z.data_mut();
    gz.copy_from_slice(g_out.data());
    match kind {
        LayerKind::Linear => {}
        LayerKind::Relu => {
            for (g, &h) in gz.iter_mut().zip(h_out.data()) {
                if h <= 0.0 {
                    *g = 0.0;
                }
            }
        }
        LayerKind::Residual => {
            for ((g, &h), &xv) in gz.iter_mut().zip(h_out.data()).zip(x.data()) {
                if h - xv <= 0.0 {
                    *g = 0.0;
                }
            }
        }
    }

    // g_x = g_z @ W^T: transpose W once (d_in·d_out, cheap next to the
    // B·d_in·d_out matmul) so the product runs as vectorizable saxpy rows
    scratch.w_t.ensure_shape(&[d_out, d_in]);
    transpose_into(w.data(), scratch.w_t.data_mut(), d_in, d_out);
    g_x.ensure_shape(&[batch, d_in]);
    g_x.fill_zero();
    matmul_acc(
        scratch.g_z.data(),
        scratch.w_t.data(),
        g_x.data_mut(),
        batch,
        d_out,
        d_in,
        threads,
    );
    if kind == LayerKind::Residual {
        g_x.axpy(1.0, g_out);
    }

    // g_w = x^T @ g_z
    g_w.ensure_shape(&[d_in, d_out]);
    matmul_tn(
        x.data(),
        scratch.g_z.data(),
        g_w.data_mut(),
        d_in,
        batch,
        d_out,
        threads,
    );

    // g_b = column sums of g_z
    g_b.ensure_shape(&[d_out]);
    g_b.fill_zero();
    let gbd = g_b.data_mut();
    let gz = scratch.g_z.data();
    for i in 0..batch {
        let row = &gz[i * d_out..(i + 1) * d_out];
        for (o, &v) in gbd.iter_mut().zip(row) {
            *o += v;
        }
    }
}

/// Fused softmax cross-entropy into `g`: returns the mean loss with the
/// 1/B mean baked into the gradient (eq. (4)). `g` is sized on first use.
pub fn softmax_xent_into(logits: &Tensor, onehot: &Tensor, g: &mut Tensor) -> f32 {
    let (batch, classes) = (logits.shape()[0], logits.shape()[1]);
    debug_assert_eq!(onehot.shape(), logits.shape());
    g.ensure_shape(&[batch, classes]);
    let inv_b = 1.0 / batch as f32;
    let mut loss = 0.0f64;
    for i in 0..batch {
        let row = &logits.data()[i * classes..(i + 1) * classes];
        let oh = &onehot.data()[i * classes..(i + 1) * classes];
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0f32;
        for &v in row {
            sum += (v - m).exp();
        }
        let lse = sum.ln();
        let g_row = &mut g.data_mut()[i * classes..(i + 1) * classes];
        for j in 0..classes {
            let logp = row[j] - m - lse;
            loss -= (oh[j] * logp) as f64;
            g_row[j] = ((row[j] - m).exp() / sum - oh[j]) * inv_b;
        }
    }
    (loss * inv_b as f64) as f32
}

/// Forward one layer of any kind into `out` — the single dispatch point
/// both backends and the oracle utilities share. Dense kinds ignore
/// `scratch`; the spatial kinds use its im2col buffers.
pub fn layer_fwd_into(
    x: &Tensor,
    w: &Tensor,
    b: &Tensor,
    layer: LayerShape,
    out: &mut Tensor,
    scratch: &mut FwdScratch,
    threads: usize,
) {
    match layer.kind {
        LayerKind::Linear | LayerKind::Relu | LayerKind::Residual => {
            dense_fwd_into(x, w, b, layer.kind, out, threads)
        }
        LayerKind::Conv3x3 => {
            let sp = layer.spatial.expect("conv layer carries spatial dims");
            conv::conv3x3_fwd_into(x, w, b, sp, out, scratch, threads)
        }
        LayerKind::MaxPool2x2 => {
            let sp = layer.spatial.expect("maxpool layer carries spatial dims");
            conv::maxpool2_fwd_into(x, sp, out)
        }
        LayerKind::Flatten => conv::flatten_fwd_into(x, out),
    }
}

/// Backward one layer of any kind into caller-owned buffers — the dispatch
/// mirror of [`layer_fwd_into`]. Parameter-free kinds leave `g_w`/`g_b`
/// sized to their `[0, 0]`/`[0]` placeholders.
#[allow(clippy::too_many_arguments)]
pub fn layer_bwd_into(
    x: &Tensor,
    w: &Tensor,
    h_out: &Tensor,
    g_out: &Tensor,
    layer: LayerShape,
    g_x: &mut Tensor,
    g_w: &mut Tensor,
    g_b: &mut Tensor,
    scratch: &mut BwdScratch,
    threads: usize,
) {
    match layer.kind {
        LayerKind::Linear | LayerKind::Relu | LayerKind::Residual => {
            dense_bwd_into(x, w, h_out, g_out, layer.kind, g_x, g_w, g_b, scratch, threads)
        }
        LayerKind::Conv3x3 => {
            let sp = layer.spatial.expect("conv layer carries spatial dims");
            conv::conv3x3_bwd_into(x, w, h_out, g_out, sp, g_x, g_w, g_b, scratch, threads)
        }
        LayerKind::MaxPool2x2 => {
            let sp = layer.spatial.expect("maxpool layer carries spatial dims");
            conv::maxpool2_bwd_into(x, h_out, g_out, sp, g_x);
            g_w.ensure_shape(&[0, 0]);
            g_b.ensure_shape(&[0]);
        }
        LayerKind::Flatten => {
            conv::flatten_bwd_into(g_out, g_x);
            g_w.ensure_shape(&[0, 0]);
            g_b.ensure_shape(&[0]);
        }
    }
}

/// Full-network forward over a layer stack; params are (W, b) pairs.
/// Evaluation/oracle utility — allocates its own activations and runs
/// single-threaded; the training hot path goes through the workspace API.
pub fn full_forward(x: &Tensor, params: &[(Tensor, Tensor)], layers: &[LayerShape]) -> Tensor {
    let mut h = x.clone();
    let mut out = Tensor::empty();
    let mut fs = FwdScratch::new();
    for ((w, b), layer) in params.iter().zip(layers) {
        layer_fwd_into(&h, w, b, *layer, &mut out, &mut fs, 1);
        std::mem::swap(&mut h, &mut out);
    }
    h
}

/// Mean loss of the whole network on (x, onehot).
pub fn full_loss(
    x: &Tensor,
    onehot: &Tensor,
    params: &[(Tensor, Tensor)],
    layers: &[LayerShape],
) -> f32 {
    let logits = full_forward(x, params, layers);
    softmax_xent_into(&logits, onehot, &mut Tensor::empty())
}

/// Whole-network gradient via per-layer backward chaining: the exact
/// computation the coordinator distributes across K modules, in one place.
/// Returns mean-scaled (g_w, g_b) per layer. Oracle utility — owns its
/// workspace; the distributed hot path reuses per-agent workspaces.
pub fn full_backward(
    x: &Tensor,
    onehot: &Tensor,
    params: &[(Tensor, Tensor)],
    layers: &[LayerShape],
) -> (f32, Vec<(Tensor, Tensor)>) {
    // forward, stashing every activation (same as the staleness buffers)
    let mut acts = vec![x.clone()];
    let mut fs = FwdScratch::new();
    for ((w, b), layer) in params.iter().zip(layers) {
        let mut h = Tensor::empty();
        layer_fwd_into(acts.last().unwrap(), w, b, *layer, &mut h, &mut fs, 1);
        acts.push(h);
    }
    let mut g = Tensor::empty();
    let loss = softmax_xent_into(acts.last().unwrap(), onehot, &mut g);
    let mut grads = Vec::with_capacity(params.len());
    let mut scratch = BwdScratch::new();
    let mut g_x = Tensor::empty();
    for i in (0..params.len()).rev() {
        let (w, _) = &params[i];
        let (mut g_w, mut g_b) = (Tensor::empty(), Tensor::empty());
        layer_bwd_into(
            &acts[i],
            w,
            &acts[i + 1],
            &g,
            layers[i],
            &mut g_x,
            &mut g_w,
            &mut g_b,
            &mut scratch,
            1,
        );
        grads.push((g_w, g_b));
        std::mem::swap(&mut g, &mut g_x);
    }
    grads.reverse();
    (loss, grads)
}

/// Classification accuracy of logits vs one-hot labels.
pub fn accuracy(logits: &Tensor, onehot: &Tensor) -> f64 {
    let (batch, classes) = (logits.shape()[0], logits.shape()[1]);
    let mut correct = 0usize;
    for i in 0..batch {
        let row = &logits.data()[i * classes..(i + 1) * classes];
        let oh = &onehot.data()[i * classes..(i + 1) * classes];
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let label = oh
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred == label {
            correct += 1;
        }
    }
    correct as f64 / batch as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::init::he_init;
    use crate::util::rng::Pcg32;

    fn rand_tensor(rng: &mut Pcg32, shape: &[usize]) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(t.data_mut(), 1.0);
        t
    }

    fn fwd(x: &Tensor, w: &Tensor, b: &Tensor, kind: LayerKind) -> Tensor {
        let mut out = Tensor::empty();
        dense_fwd_into(x, w, b, kind, &mut out, 1);
        out
    }

    #[test]
    fn dense_fwd_known_values() {
        // x = [[1, 2]], W = [[1, 0], [0, 1]], b = [0.5, -10]
        let x = Tensor::from_vec(&[1, 2], vec![1.0, 2.0]).unwrap();
        let w = Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        let b = Tensor::from_vec(&[2], vec![0.5, -10.0]).unwrap();
        assert_eq!(fwd(&x, &w, &b, LayerKind::Linear).data(), &[1.5, -8.0]);
        assert_eq!(fwd(&x, &w, &b, LayerKind::Relu).data(), &[1.5, 0.0]);
        assert_eq!(fwd(&x, &w, &b, LayerKind::Residual).data(), &[2.5, 2.0]);
    }

    #[test]
    fn softmax_xent_uniform_is_log_c() {
        let logits = Tensor::zeros(&[4, 10]);
        let mut onehot = Tensor::zeros(&[4, 10]);
        for i in 0..4 {
            onehot.data_mut()[i * 10 + i] = 1.0;
        }
        let mut g = Tensor::empty();
        let loss = softmax_xent_into(&logits, &onehot, &mut g);
        assert!((loss - (10.0f32).ln()).abs() < 1e-5);
        // gradient rows sum to zero
        for i in 0..4 {
            let s: f32 = g.data()[i * 10..(i + 1) * 10].iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_stable_with_large_logits() {
        let logits = Tensor::from_vec(&[1, 2], vec![1000.0, -1000.0]).unwrap();
        let onehot = Tensor::from_vec(&[1, 2], vec![1.0, 0.0]).unwrap();
        let mut g = Tensor::empty();
        let loss = softmax_xent_into(&logits, &onehot, &mut g);
        assert!(loss.is_finite() && loss < 1e-3);
        assert!(g.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn bwd_matches_finite_difference_all_kinds() {
        let mut rng = Pcg32::new(1);
        for kind in [LayerKind::Linear, LayerKind::Relu, LayerKind::Residual] {
            let (b_sz, d) = (4, 6);
            let x = rand_tensor(&mut rng, &[b_sz, d]);
            let w = he_init(&mut rng, d, d);
            let bias = rand_tensor(&mut rng, &[d]);
            let layer = LayerShape::new(kind, d, d).unwrap();
            let err = grad_check::check_layer(&x, &w, &bias, layer, 1e-3, &mut rng);
            assert!(err < 2e-2, "{kind:?}: fd mismatch {err}");
        }
    }

    #[test]
    fn full_backward_matches_finite_difference() {
        let mut rng = Pcg32::new(2);
        let layers = resmlp_layers(8, 6, 2, 4);
        let params: Vec<(Tensor, Tensor)> = layers
            .iter()
            .map(|l| (he_init(&mut rng, l.d_in, l.d_out), Tensor::zeros(&[l.d_out])))
            .collect();
        let x = rand_tensor(&mut rng, &[5, 8]);
        let mut onehot = Tensor::zeros(&[5, 4]);
        for i in 0..5 {
            let c = rng.below(4);
            onehot.data_mut()[i * 4 + c] = 1.0;
        }
        let err = grad_check::check_full(&x, &onehot, &params, &layers, 1e-3, &mut rng);
        assert!(err < 2e-2, "fd mismatch {err}");
    }

    #[test]
    fn accuracy_basics() {
        let logits = Tensor::from_vec(&[2, 3], vec![1.0, 5.0, 0.0, 9.0, 1.0, 1.0]).unwrap();
        let onehot = Tensor::from_vec(&[2, 3], vec![0.0, 1.0, 0.0, 0.0, 0.0, 1.0]).unwrap();
        assert_eq!(accuracy(&logits, &onehot), 0.5);
    }

    #[test]
    fn matmul_variants_agree_with_naive() {
        let mut rng = Pcg32::new(5);
        // m > KBLOCK would need k > KBLOCK to exercise blocking; keep both
        let (m, k, n) = (7, 70, 6);
        let a = rand_tensor(&mut rng, &[m, k]);
        let at = rand_tensor(&mut rng, &[k, m]);
        let b = rand_tensor(&mut rng, &[k, n]);

        // acc: a @ b
        let mut out = vec![0.0; m * n];
        matmul_acc(a.data(), b.data(), &mut out, m, k, n, 1);
        for i in 0..m {
            for j in 0..n {
                let want: f32 = (0..k).map(|kk| a.data()[i * k + kk] * b.data()[kk * n + j]).sum();
                assert!((out[i * n + j] - want).abs() < 1e-3);
            }
        }
        // tn: at^T @ b
        let mut out2 = vec![0.0; m * n];
        matmul_tn(at.data(), b.data(), &mut out2, m, k, n, 1);
        for i in 0..m {
            for j in 0..n {
                let want: f32 = (0..k).map(|kk| at.data()[kk * m + i] * b.data()[kk * n + j]).sum();
                assert!((out2[i * n + j] - want).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Pcg32::new(6);
        let t = rand_tensor(&mut rng, &[3, 5]);
        let mut tt = vec![0.0; 15];
        transpose_into(t.data(), &mut tt, 3, 5);
        let mut back = vec![0.0; 15];
        transpose_into(&tt, &mut back, 5, 3);
        assert_eq!(t.data(), &back[..]);
    }

    #[test]
    fn kernels_bit_identical_across_thread_counts() {
        // fixed chunk boundaries + ascending-k accumulation per output
        // element ⇒ any worker count computes the same bits. Sizes chosen
        // so plan_threads actually fans out (> MIN_MACS_PER_THREAD each).
        let mut rng = Pcg32::new(7);
        let (m, k, n) = (64, 160, 128); // 1.3M MACs ⇒ 2 workers at threads=2
        let a = rand_tensor(&mut rng, &[m, k]);
        let b = rand_tensor(&mut rng, &[k, n]);
        let at = rand_tensor(&mut rng, &[k, m]);
        for threads in [2usize, 3, 5] {
            let mut serial = vec![0.0; m * n];
            matmul_acc(a.data(), b.data(), &mut serial, m, k, n, 1);
            let mut par = vec![0.0; m * n];
            matmul_acc(a.data(), b.data(), &mut par, m, k, n, threads);
            assert_eq!(serial, par, "matmul_acc threads={threads}");

            let mut serial2 = vec![0.0; m * n];
            matmul_tn(at.data(), b.data(), &mut serial2, m, k, n, 1);
            let mut par2 = vec![0.0; m * n];
            matmul_tn(at.data(), b.data(), &mut par2, m, k, n, threads);
            assert_eq!(serial2, par2, "matmul_tn threads={threads}");
        }
    }

    #[test]
    fn dense_layers_bit_identical_across_thread_counts() {
        let mut rng = Pcg32::new(8);
        let (b_sz, d) = (64, 128); // above the fan-out threshold
        let x = rand_tensor(&mut rng, &[b_sz, d]);
        let w = he_init(&mut rng, d, d);
        let bias = rand_tensor(&mut rng, &[d]);
        for kind in [LayerKind::Relu, LayerKind::Residual] {
            let (mut h1, mut h4) = (Tensor::empty(), Tensor::empty());
            dense_fwd_into(&x, &w, &bias, kind, &mut h1, 1);
            dense_fwd_into(&x, &w, &bias, kind, &mut h4, 4);
            assert_eq!(h1, h4, "{kind:?} fwd");

            let g = rand_tensor(&mut rng, &[b_sz, d]);
            let run = |threads: usize| {
                let (mut gx, mut gw, mut gb) = (Tensor::empty(), Tensor::empty(), Tensor::empty());
                let mut scratch = BwdScratch::new();
                dense_bwd_into(
                    &x, &w, &h1, &g, kind, &mut gx, &mut gw, &mut gb, &mut scratch, threads,
                );
                (gx, gw, gb)
            };
            assert_eq!(run(1), run(4), "{kind:?} bwd");
        }
    }

    #[test]
    fn resolve_threads_auto_is_at_least_one() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }
}
