//! Convolutional kernels over flattened-NCHW `[B, C·H·W]` activations:
//! 3×3/stride-1/zero-pad-1 conv (+ReLU), 2×2/stride-2 max pool, and the
//! NCHW → dense `flatten` marker.
//!
//! §Perf — same contract as the dense kernels (`nn` §Perf): everything is
//! an in-place, caller-owned-workspace `_into` variant. The conv is
//! im2col-based so both matmuls reuse the k-blocked, thread-parallel
//! `matmul_acc`/`matmul_tn` primitives: the column matrix has B·H·W rows,
//! so row-chunk fan-out has plenty of parallelism even at small batch.
//! im2col / col2im / the NCHW↔row-major reorders run single-threaded —
//! they are O(elements) memory passes next to the O(elements·9·C) matmuls
//! — which keeps every reduction in one fixed order: any `--compute-threads`
//! computes the same bits (asserted in the nn tests).

use crate::nn::layer::Spatial;
use crate::nn::{matmul_acc, matmul_tn, transpose_into, BwdScratch};
use crate::tensor::Tensor;

/// Caller-owned scratch for one spatial layer's forward pass: the im2col
/// column matrix and the row-major matmul output awaiting its NCHW reorder.
/// Sized lazily on first use ([`Tensor::ensure_shape`]), allocation-free
/// after that; dense layers never touch it.
#[derive(Debug, Clone, Default)]
pub struct FwdScratch {
    /// im2col of the input, [B·H·W, 9·c_in]
    pub col: Tensor,
    /// conv matmul output before the NCHW reorder, [B·H·W, c_out]
    pub tmp: Tensor,
}

impl FwdScratch {
    pub fn new() -> FwdScratch {
        FwdScratch {
            col: Tensor::empty(),
            tmp: Tensor::empty(),
        }
    }
}

/// col[b·HW + i·W + j, c·9 + dr·3 + dc] = x[b, c·HW + (i+dr−1)·W + (j+dc−1)]
/// (zero outside the image). One fixed scan order — deterministic.
fn im2col_3x3(x: &[f32], col: &mut [f32], batch: usize, c: usize, h: usize, w: usize) {
    let hw = h * w;
    let cols = c * 9;
    debug_assert_eq!(x.len(), batch * c * hw);
    debug_assert_eq!(col.len(), batch * hw * cols);
    for bi in 0..batch {
        let x_img = &x[bi * c * hw..(bi + 1) * c * hw];
        let col_img = &mut col[bi * hw * cols..(bi + 1) * hw * cols];
        for i in 0..h {
            for j in 0..w {
                let row = &mut col_img[(i * w + j) * cols..(i * w + j + 1) * cols];
                for cc in 0..c {
                    let plane = &x_img[cc * hw..(cc + 1) * hw];
                    for dr in 0..3usize {
                        let ii = (i + dr).wrapping_sub(1);
                        for dc in 0..3usize {
                            let jj = (j + dc).wrapping_sub(1);
                            row[cc * 9 + dr * 3 + dc] = if ii < h && jj < w {
                                plane[ii * w + jj]
                            } else {
                                0.0
                            };
                        }
                    }
                }
            }
        }
    }
}

/// Scatter-add the inverse of [`im2col_3x3`]: g_x[...] += g_col[...] over
/// the same index map, in the same fixed scan order (deterministic).
fn col2im_3x3(g_col: &[f32], g_x: &mut [f32], batch: usize, c: usize, h: usize, w: usize) {
    let hw = h * w;
    let cols = c * 9;
    debug_assert_eq!(g_x.len(), batch * c * hw);
    debug_assert_eq!(g_col.len(), batch * hw * cols);
    for bi in 0..batch {
        let gx_img = &mut g_x[bi * c * hw..(bi + 1) * c * hw];
        let gcol_img = &g_col[bi * hw * cols..(bi + 1) * hw * cols];
        for i in 0..h {
            for j in 0..w {
                let row = &gcol_img[(i * w + j) * cols..(i * w + j + 1) * cols];
                for cc in 0..c {
                    let plane = &mut gx_img[cc * hw..(cc + 1) * hw];
                    for dr in 0..3usize {
                        let ii = (i + dr).wrapping_sub(1);
                        for dc in 0..3usize {
                            let jj = (j + dc).wrapping_sub(1);
                            if ii < h && jj < w {
                                plane[ii * w + jj] += row[cc * 9 + dr * 3 + dc];
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Forward conv3x3 (+bias +ReLU) into `out`: x [B, c_in·H·W] NCHW,
/// w [9·c_in, c_out], b [c_out], out [B, c_out·H·W] NCHW. `out` and
/// `scratch` are sized on first use and reused allocation-free afterwards.
pub fn conv3x3_fwd_into(
    x: &Tensor,
    w: &Tensor,
    b: &Tensor,
    sp: Spatial,
    out: &mut Tensor,
    scratch: &mut FwdScratch,
    threads: usize,
) {
    let batch = x.shape()[0];
    let (c_in, h, ww, c_out) = (sp.c_in, sp.h, sp.w, sp.c_out);
    let hw = h * ww;
    debug_assert_eq!(x.shape()[1], c_in * hw);
    debug_assert_eq!(w.shape(), &[9 * c_in, c_out]);
    debug_assert_eq!(b.len(), c_out);

    scratch.col.ensure_shape(&[batch * hw, 9 * c_in]);
    im2col_3x3(x.data(), scratch.col.data_mut(), batch, c_in, h, ww);
    scratch.tmp.ensure_shape(&[batch * hw, c_out]);
    scratch.tmp.fill_zero();
    matmul_acc(
        scratch.col.data(),
        w.data(),
        scratch.tmp.data_mut(),
        batch * hw,
        9 * c_in,
        c_out,
        threads,
    );

    // bias + ReLU + row-major [B·HW, c_out] → NCHW [B, c_out·HW] reorder
    out.ensure_shape(&[batch, c_out * hw]);
    let od = out.data_mut();
    let (tmp, bd) = (scratch.tmp.data(), b.data());
    for bi in 0..batch {
        let o_img = &mut od[bi * c_out * hw..(bi + 1) * c_out * hw];
        let t_img = &tmp[bi * hw * c_out..(bi + 1) * hw * c_out];
        for p in 0..hw {
            let t_row = &t_img[p * c_out..(p + 1) * c_out];
            for cc in 0..c_out {
                o_img[cc * hw + p] = (t_row[cc] + bd[cc]).max(0.0);
            }
        }
    }
}

/// Backward conv3x3: mirrors [`conv3x3_fwd_into`]'s z = col·W + b,
/// h = relu(z). `h_out` must be the forward output of exactly these
/// (x, w, b) — the ReLU mask is reconstructed from it like the dense path.
#[allow(clippy::too_many_arguments)]
pub fn conv3x3_bwd_into(
    x: &Tensor,
    w: &Tensor,
    h_out: &Tensor,
    g_out: &Tensor,
    sp: Spatial,
    g_x: &mut Tensor,
    g_w: &mut Tensor,
    g_b: &mut Tensor,
    scratch: &mut BwdScratch,
    threads: usize,
) {
    let batch = x.shape()[0];
    let (c_in, h, ww, c_out) = (sp.c_in, sp.h, sp.w, sp.c_out);
    let hw = h * ww;
    debug_assert_eq!(h_out.shape(), &[batch, c_out * hw]);
    debug_assert_eq!(g_out.shape(), &[batch, c_out * hw]);

    // g_z = g_out ⊙ mask(h > 0), NCHW
    scratch.g_z.ensure_shape(&[batch, c_out * hw]);
    let gz = scratch.g_z.data_mut();
    gz.copy_from_slice(g_out.data());
    for (g, &hv) in gz.iter_mut().zip(h_out.data()) {
        if hv <= 0.0 {
            *g = 0.0;
        }
    }

    // NCHW → row-major [B·HW, c_out] (the matmul layout)
    scratch.g_tmp.ensure_shape(&[batch * hw, c_out]);
    let gt = scratch.g_tmp.data_mut();
    let gz = scratch.g_z.data();
    for bi in 0..batch {
        let gz_img = &gz[bi * c_out * hw..(bi + 1) * c_out * hw];
        let gt_img = &mut gt[bi * hw * c_out..(bi + 1) * hw * c_out];
        for cc in 0..c_out {
            let plane = &gz_img[cc * hw..(cc + 1) * hw];
            for p in 0..hw {
                gt_img[p * c_out + cc] = plane[p];
            }
        }
    }

    // g_w = col(x)^T @ g_tmp  (col recomputed — the forward's col lives in
    // the per-layer FwdScratch, not here)
    scratch.col.ensure_shape(&[batch * hw, 9 * c_in]);
    im2col_3x3(x.data(), scratch.col.data_mut(), batch, c_in, h, ww);
    g_w.ensure_shape(&[9 * c_in, c_out]);
    matmul_tn(
        scratch.col.data(),
        scratch.g_tmp.data(),
        g_w.data_mut(),
        9 * c_in,
        batch * hw,
        c_out,
        threads,
    );

    // g_b = column sums of g_tmp
    g_b.ensure_shape(&[c_out]);
    g_b.fill_zero();
    let gbd = g_b.data_mut();
    let gt = scratch.g_tmp.data();
    for row in gt.chunks_exact(c_out) {
        for (o, &v) in gbd.iter_mut().zip(row) {
            *o += v;
        }
    }

    // g_col = g_tmp @ W^T (saxpy form via the transposed weights), then
    // scatter-add back through the im2col map
    scratch.w_t.ensure_shape(&[c_out, 9 * c_in]);
    transpose_into(w.data(), scratch.w_t.data_mut(), 9 * c_in, c_out);
    scratch.g_col.ensure_shape(&[batch * hw, 9 * c_in]);
    scratch.g_col.fill_zero();
    matmul_acc(
        scratch.g_tmp.data(),
        scratch.w_t.data(),
        scratch.g_col.data_mut(),
        batch * hw,
        c_out,
        9 * c_in,
        threads,
    );
    g_x.ensure_shape(&[batch, c_in * hw]);
    g_x.fill_zero();
    col2im_3x3(scratch.g_col.data(), g_x.data_mut(), batch, c_in, h, ww);
}

/// Forward 2×2/stride-2 max pool: x [B, c·H·W] → out [B, c·(H/2)·(W/2)].
pub fn maxpool2_fwd_into(x: &Tensor, sp: Spatial, out: &mut Tensor) {
    let batch = x.shape()[0];
    let (c, h, w) = (sp.c_in, sp.h, sp.w);
    let (ho, wo) = (h / 2, w / 2);
    debug_assert_eq!(x.shape()[1], c * h * w);
    out.ensure_shape(&[batch, c * ho * wo]);
    let od = out.data_mut();
    let xd = x.data();
    for bi in 0..batch {
        for cc in 0..c {
            let plane = &xd[(bi * c + cc) * h * w..(bi * c + cc + 1) * h * w];
            let o_plane = &mut od[(bi * c + cc) * ho * wo..(bi * c + cc + 1) * ho * wo];
            for oi in 0..ho {
                for oj in 0..wo {
                    let (i, j) = (2 * oi, 2 * oj);
                    let m = plane[i * w + j]
                        .max(plane[i * w + j + 1])
                        .max(plane[(i + 1) * w + j])
                        .max(plane[(i + 1) * w + j + 1]);
                    o_plane[oi * wo + oj] = m;
                }
            }
        }
    }
}

/// Backward max pool: the gradient routes to the FIRST window position (in
/// (0,0),(0,1),(1,0),(1,1) scan order) matching the pooled value —
/// deterministic under ties. `h_out` is the forward output on this `x`.
pub fn maxpool2_bwd_into(x: &Tensor, h_out: &Tensor, g_out: &Tensor, sp: Spatial, g_x: &mut Tensor) {
    let batch = x.shape()[0];
    let (c, h, w) = (sp.c_in, sp.h, sp.w);
    let (ho, wo) = (h / 2, w / 2);
    debug_assert_eq!(g_out.shape(), &[batch, c * ho * wo]);
    g_x.ensure_shape(&[batch, c * h * w]);
    g_x.fill_zero();
    let gxd = g_x.data_mut();
    let (xd, hd, gd) = (x.data(), h_out.data(), g_out.data());
    for bi in 0..batch {
        for cc in 0..c {
            let plane = &xd[(bi * c + cc) * h * w..(bi * c + cc + 1) * h * w];
            let gx_plane = &mut gxd[(bi * c + cc) * h * w..(bi * c + cc + 1) * h * w];
            let base_o = (bi * c + cc) * ho * wo;
            for oi in 0..ho {
                for oj in 0..wo {
                    let m = hd[base_o + oi * wo + oj];
                    let g = gd[base_o + oi * wo + oj];
                    let (i, j) = (2 * oi, 2 * oj);
                    for (di, dj) in [(0, 0), (0, 1), (1, 0), (1, 1)] {
                        if plane[(i + di) * w + j + dj] == m {
                            gx_plane[(i + di) * w + j + dj] += g;
                            break;
                        }
                    }
                }
            }
        }
    }
}

/// Flatten forward: identity on the flat `[B, d]` buffer (the NCHW → dense
/// boundary marker — activations are already flattened NCHW everywhere).
pub fn flatten_fwd_into(x: &Tensor, out: &mut Tensor) {
    out.copy_resize(x);
}

/// Flatten backward: identity.
pub fn flatten_bwd_into(g_out: &Tensor, g_x: &mut Tensor) {
    g_x.copy_resize(g_out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::layer::LayerShape;
    use crate::util::rng::Pcg32;

    fn rand_tensor(rng: &mut Pcg32, shape: &[usize]) -> Tensor {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(t.data_mut(), 1.0);
        t
    }

    #[test]
    fn im2col_known_values() {
        // one 1-channel 2x2 image [[1,2],[3,4]]
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let mut col = vec![0.0; 4 * 9];
        im2col_3x3(&x, &mut col, 1, 1, 2, 2);
        // output position (0,0): 3x3 window centered there, zero-padded
        assert_eq!(&col[0..9], &[0.0, 0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 3.0, 4.0]);
        // output position (1,1): window centered on value 4
        assert_eq!(&col[27..36], &[1.0, 2.0, 0.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn col2im_adjoint_of_im2col() {
        // <im2col(x), g> == <x, col2im(g)> — adjointness pins the scatter
        let mut rng = Pcg32::new(3);
        let (b, c, h, w) = (2usize, 3usize, 4usize, 5usize);
        let x = rand_tensor(&mut rng, &[b, c * h * w]);
        let mut col = vec![0.0; b * h * w * c * 9];
        im2col_3x3(x.data(), &mut col, b, c, h, w);
        let g_col = rand_tensor(&mut rng, &[b * h * w, c * 9]);
        let mut g_x = vec![0.0; b * c * h * w];
        col2im_3x3(g_col.data(), &mut g_x, b, c, h, w);
        let lhs: f64 = col.iter().zip(g_col.data()).map(|(&a, &b)| (a * b) as f64).sum();
        let rhs: f64 = x.data().iter().zip(&g_x).map(|(&a, &b)| (a * b) as f64).sum();
        assert!((lhs - rhs).abs() < 1e-3, "{lhs} vs {rhs}");
    }

    #[test]
    fn conv_identity_kernel_is_relu() {
        // W that picks the center tap of channel 0 reproduces relu(x + b)
        let (c_in, h, w, c_out) = (1usize, 3usize, 3usize, 1usize);
        let sp = LayerShape::conv3x3(c_in, h, w, c_out).unwrap().spatial.unwrap();
        let mut wt = Tensor::zeros(&[9, 1]);
        wt.data_mut()[4] = 1.0; // dr=1, dc=1: the center tap
        let b = Tensor::from_vec(&[1], vec![-0.5]).unwrap();
        let x = Tensor::from_vec(
            &[1, 9],
            vec![1.0, -2.0, 0.25, 3.0, 0.5, -1.0, 2.0, 0.75, -0.25],
        )
        .unwrap();
        let mut out = Tensor::empty();
        let mut fs = FwdScratch::new();
        conv3x3_fwd_into(&x, &wt, &b, sp, &mut out, &mut fs, 1);
        let want: Vec<f32> = x.data().iter().map(|&v| (v - 0.5).max(0.0)).collect();
        assert_eq!(out.data(), &want[..]);
    }

    #[test]
    fn conv_backward_masks_inactive_relu_exactly() {
        // identity center-tap kernel: h = relu(x + b) elementwise, so the
        // backward must reproduce the dense-ReLU mask bit for bit:
        // g_x[p] = g_out[p]·1[h[p] > 0] (only the center tap routes back),
        // g_b = Σ_p masked g — pins the mask without finite differences
        let sp = LayerShape::conv3x3(1, 3, 3, 1).unwrap().spatial.unwrap();
        let mut wt = Tensor::zeros(&[9, 1]);
        wt.data_mut()[4] = 1.0;
        let b = Tensor::from_vec(&[1], vec![-0.5]).unwrap();
        let x = Tensor::from_vec(
            &[1, 9],
            vec![1.0, -2.0, 0.25, 3.0, 0.5, -1.0, 2.0, 0.75, -0.25],
        )
        .unwrap();
        let mut h = Tensor::empty();
        let mut fs = FwdScratch::new();
        conv3x3_fwd_into(&x, &wt, &b, sp, &mut h, &mut fs, 1);

        let g = Tensor::from_vec(
            &[1, 9],
            vec![1.0, 1.0, 1.0, -2.0, 0.5, 1.0, 1.0, 3.0, 1.0],
        )
        .unwrap();
        let (mut gx, mut gw, mut gb) = (Tensor::empty(), Tensor::empty(), Tensor::empty());
        let mut scratch = BwdScratch::new();
        conv3x3_bwd_into(&x, &wt, &h, &g, sp, &mut gx, &mut gw, &mut gb, &mut scratch, 1);

        let mask: Vec<f32> = h.data().iter().map(|&v| if v > 0.0 { 1.0 } else { 0.0 }).collect();
        let want_gx: Vec<f32> =
            g.data().iter().zip(&mask).map(|(&gv, &m)| gv * m).collect();
        assert_eq!(gx.data(), &want_gx[..]);
        let want_gb: f32 = want_gx.iter().sum();
        assert_eq!(gb.data(), &[want_gb]);
        assert_eq!(gw.shape(), &[9, 1]);
    }

    #[test]
    fn maxpool_known_values_and_routing() {
        let sp = LayerShape::maxpool2(1, 2, 2).unwrap().spatial.unwrap();
        let x = Tensor::from_vec(&[1, 4], vec![1.0, 4.0, 3.0, 2.0]).unwrap();
        let mut out = Tensor::empty();
        maxpool2_fwd_into(&x, sp, &mut out);
        assert_eq!(out.data(), &[4.0]);
        let g = Tensor::from_vec(&[1, 1], vec![2.5]).unwrap();
        let mut gx = Tensor::empty();
        maxpool2_bwd_into(&x, &out, &g, sp, &mut gx);
        assert_eq!(gx.data(), &[0.0, 2.5, 0.0, 0.0]);
    }

    #[test]
    fn maxpool_ties_route_to_first_position() {
        let sp = LayerShape::maxpool2(1, 2, 2).unwrap().spatial.unwrap();
        let x = Tensor::from_vec(&[1, 4], vec![7.0, 7.0, 7.0, 7.0]).unwrap();
        let mut out = Tensor::empty();
        maxpool2_fwd_into(&x, sp, &mut out);
        let g = Tensor::from_vec(&[1, 1], vec![1.0]).unwrap();
        let mut gx = Tensor::empty();
        maxpool2_bwd_into(&x, &out, &g, sp, &mut gx);
        assert_eq!(gx.data(), &[1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn flatten_is_identity_both_ways() {
        let mut rng = Pcg32::new(4);
        let x = rand_tensor(&mut rng, &[3, 12]);
        let mut out = Tensor::empty();
        flatten_fwd_into(&x, &mut out);
        assert_eq!(out, x);
        let mut gx = Tensor::empty();
        flatten_bwd_into(&x, &mut gx);
        assert_eq!(gx, x);
    }

    #[test]
    fn conv_kernels_bit_identical_across_thread_counts() {
        // sizes above the matmul fan-out threshold: B·HW = 2048 rows,
        // 2048·36·16 ≈ 1.2M MACs ⇒ real row-chunk fan-out at threads ≥ 2
        let mut rng = Pcg32::new(5);
        let sp = LayerShape::conv3x3(4, 16, 16, 16).unwrap().spatial.unwrap();
        let x = rand_tensor(&mut rng, &[8, 4 * 256]);
        let w = rand_tensor(&mut rng, &[36, 16]);
        let b = rand_tensor(&mut rng, &[16]);
        let run_fwd = |threads: usize| {
            let mut out = Tensor::empty();
            let mut fs = FwdScratch::new();
            conv3x3_fwd_into(&x, &w, &b, sp, &mut out, &mut fs, threads);
            out
        };
        let h1 = run_fwd(1);
        for threads in [2usize, 3, 5] {
            assert_eq!(h1, run_fwd(threads), "fwd threads={threads}");
        }
        let g = rand_tensor(&mut rng, &[8, 16 * 256]);
        let run_bwd = |threads: usize| {
            let (mut gx, mut gw, mut gb) = (Tensor::empty(), Tensor::empty(), Tensor::empty());
            let mut scratch = BwdScratch::new();
            conv3x3_bwd_into(&x, &w, &h1, &g, sp, &mut gx, &mut gw, &mut gb, &mut scratch, threads);
            (gx, gw, gb)
        };
        let b1 = run_bwd(1);
        for threads in [2usize, 4] {
            assert_eq!(b1, run_bwd(threads), "bwd threads={threads}");
        }
    }
}
