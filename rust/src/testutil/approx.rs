//! Approximate-equality assertions for floats and tensors.

use crate::tensor::Tensor;

pub fn assert_close_f64(got: f64, want: f64, tol: f64, label: &str) {
    let denom = want.abs().max(1.0);
    assert!(
        (got - want).abs() / denom <= tol,
        "{label}: got {got}, want {want} (tol {tol})"
    );
}

pub fn assert_close_f32(got: f32, want: f32, tol: f32, label: &str) {
    assert_close_f64(got as f64, want as f64, tol as f64, label);
}

/// Max-abs-difference tensor comparison with shape check.
pub fn assert_tensors_close(got: &Tensor, want: &Tensor, tol: f32, label: &str) {
    assert_eq!(got.shape(), want.shape(), "{label}: shape mismatch");
    let diff = got.max_abs_diff(want);
    assert!(
        diff <= tol,
        "{label}: max abs diff {diff} > tol {tol}"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn close_passes() {
        assert_close_f64(1.0000001, 1.0, 1e-5, "x");
        let a = Tensor::from_vec(&[2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::from_vec(&[2], vec![1.0, 2.0 + 1e-7]).unwrap();
        assert_tensors_close(&a, &b, 1e-5, "t");
    }

    #[test]
    #[should_panic]
    fn far_fails() {
        assert_close_f64(2.0, 1.0, 1e-5, "x");
    }
}
