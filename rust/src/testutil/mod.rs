//! Test infrastructure built in-house (no proptest offline): a seeded
//! property-testing harness and approximate-equality helpers.

pub mod approx;
pub mod prop;

pub use approx::{assert_close_f32, assert_close_f64, assert_tensors_close};
pub use prop::forall;
