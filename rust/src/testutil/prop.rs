//! Mini property-testing harness: generate `cases` random inputs from a
//! seeded RNG, check the property on each, and report the failing case's
//! debug form plus the seed that reproduces it.

use crate::util::rng::Pcg32;

/// Run `prop` on `cases` random inputs from `gen`. Panics on the first
/// failing case with enough context to reproduce (global seed + index).
pub fn forall<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    gen: impl Fn(&mut Pcg32) -> T,
    prop: impl Fn(&T) -> bool,
) {
    let mut rng = Pcg32::new(seed);
    for i in 0..cases {
        let mut case_rng = rng.fork(i as u64);
        let input = gen(&mut case_rng);
        if !prop(&input) {
            panic!(
                "property failed on case {i}/{cases} (seed {seed}):\n{input:#?}"
            );
        }
    }
}

/// Like [`forall`] but the property returns a Result-style message.
pub fn forall_msg<T: std::fmt::Debug>(
    seed: u64,
    cases: usize,
    gen: impl Fn(&mut Pcg32) -> T,
    prop: impl Fn(&T) -> std::result::Result<(), String>,
) {
    let mut rng = Pcg32::new(seed);
    for i in 0..cases {
        let mut case_rng = rng.fork(i as u64);
        let input = gen(&mut case_rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed on case {i}/{cases} (seed {seed}): {msg}\n{input:#?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_on_true_property() {
        forall(1, 100, |r| r.below(100), |&x| x < 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn fails_with_case_context() {
        forall(1, 100, |r| r.below(100), |&x| x < 50);
    }

    #[test]
    fn forall_msg_reports_reason() {
        forall_msg(2, 10, |r| r.f64(), |&x| {
            if x < 1.0 { Ok(()) } else { Err(format!("{x} >= 1")) }
        });
    }
}
