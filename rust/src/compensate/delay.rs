//! DC-S3GD-style delay compensation (Rigazzi et al. 2019, after Zheng et
//! al.'s DC-ASGD): first-order Taylor correction of the stale gradient.
//!
//! The stale gradient g was evaluated at the forward-time snapshot w_snap
//! (eq. (10)); a fresh gradient at the current weights w_now would be
//! approximately `g + H·(w_now − w_snap)`. The Hessian is approximated by
//! its diagonal outer-product surrogate `λ·g⊙g`, giving the cheap
//! element-wise update
//!
//! ```text
//! g_eff = g + λ · g ⊙ g ⊙ (w_now − w_snap)
//! ```
//!
//! applied in place on the owned gradient buffers — one pass, no copies.
//! λ = 0 degenerates to the raw stale gradient (the `None` baseline) —
//! asserted bit-exactly in the tests below.

use crate::compensate::{Compensated, Compensator};
use crate::tensor::Tensor;

/// Per-module delay-compensation strategy. Stateless between iterations:
/// the snapshot it corrects against rides in the stash, not here.
#[derive(Debug, Clone, Copy)]
pub struct DelayComp {
    lambda: f64,
}

impl DelayComp {
    pub fn new(lambda: f64) -> DelayComp {
        DelayComp { lambda }
    }
}

/// g += λ · g ⊙ g ⊙ (now − snap), element-wise in place on one tensor;
/// returns the squared norm of the correction term added (accumulated
/// here so the hot path walks the parameters exactly once).
fn correct_in_place(g: &mut Tensor, now: &Tensor, snap: &Tensor, lambda: f32) -> f64 {
    let (g, n, s) = (g.data_mut(), now.data(), snap.data());
    debug_assert_eq!(g.len(), n.len());
    debug_assert_eq!(n.len(), s.len());
    let mut sq = 0.0f64;
    for i in 0..g.len() {
        let corr = lambda * g[i] * g[i] * (n[i] - s[i]);
        g[i] += corr;
        sq += corr as f64 * corr as f64;
    }
    sq
}

impl Compensator for DelayComp {
    fn compensate(
        &mut self,
        grads: &mut [(Tensor, Tensor)],
        now: &[(Tensor, Tensor)],
        snapshot: &[(Tensor, Tensor)],
    ) -> Compensated {
        debug_assert_eq!(grads.len(), now.len());
        debug_assert_eq!(grads.len(), snapshot.len());
        let lambda = self.lambda as f32;
        let mut sq = 0.0f64;
        for (i, (g_w, g_b)) in grads.iter_mut().enumerate() {
            sq += correct_in_place(g_w, &now[i].0, &snapshot[i].0, lambda);
            sq += correct_in_place(g_b, &now[i].1, &snapshot[i].1, lambda);
        }
        Compensated::Apply {
            correction_norm: sq.sqrt(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compensate::test_grads;

    fn apply(dc: &mut DelayComp, g: &[(Tensor, Tensor)], now: &[(Tensor, Tensor)],
             snap: &[(Tensor, Tensor)]) -> (Vec<(Tensor, Tensor)>, f64) {
        let mut grads = g.to_vec();
        match dc.compensate(&mut grads, now, snap) {
            Compensated::Apply { correction_norm } => (grads, correction_norm),
            other => panic!("expected Apply, got {other:?}"),
        }
    }

    #[test]
    fn lambda_zero_is_bit_identical_to_none() {
        let g = test_grads(&[0.3, -1.2]);
        let now = test_grads(&[1.0, 2.0]);
        let snap = test_grads(&[0.5, 1.5]);
        let mut dc = DelayComp::new(0.0);
        let (grads, norm) = apply(&mut dc, &g, &now, &snap);
        assert_eq!(norm, 0.0);
        for ((aw, ab), (bw, bb)) in grads.iter().zip(&g) {
            assert_eq!(aw, bw);
            assert_eq!(ab, bb);
        }
    }

    #[test]
    fn no_drift_means_no_correction() {
        // w_now == w_snap ⇒ the correction term vanishes for any λ
        let g = test_grads(&[0.7]);
        let w = test_grads(&[2.0]);
        let mut dc = DelayComp::new(3.0);
        let (grads, norm) = apply(&mut dc, &g, &w, &w);
        assert_eq!(norm, 0.0);
        assert_eq!(&grads[0].0, &g[0].0);
    }

    #[test]
    fn correction_matches_manual_formula() {
        let g = test_grads(&[2.0]); // W = [2, -2], b = [1]
        let now = test_grads(&[1.0]); // W = [1, -1], b = [0.5]
        let snap = test_grads(&[0.0]); // zeros
        let mut dc = DelayComp::new(0.5);
        let (grads, norm) = apply(&mut dc, &g, &now, &snap);
        // W[0]: 2 + 0.5·2·2·(1−0) = 4; W[1]: −2 + 0.5·4·(−1) = −4
        assert_eq!(grads[0].0.data(), &[4.0, -4.0]);
        // b[0]: 1 + 0.5·1·1·0.5 = 1.25
        assert_eq!(grads[0].1.data(), &[1.25]);
        // ‖correction‖ = sqrt(2² + 2² + 0.25²)
        assert!((norm - (4.0 + 4.0 + 0.0625f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn larger_staleness_drift_grows_the_correction() {
        let g = test_grads(&[1.0]);
        let snap = test_grads(&[0.0]);
        let near = test_grads(&[0.1]);
        let far = test_grads(&[1.0]);
        let mut dc = DelayComp::new(1.0);
        let (_, n_near) = apply(&mut dc, &g, &near, &snap);
        let (_, n_far) = apply(&mut dc, &g, &far, &snap);
        assert!(n_far > n_near, "{n_far} <= {n_near}");
    }
}
