//! Staleness compensation: pluggable gradient-correction strategies.
//!
//! The paper applies every stale gradient raw (eq. (13a)), no matter how
//! far behind the forward-time snapshot is — staleness grows as 2(K−1−k),
//! which is exactly the regime where deeper pipeline splits degrade
//! convergence. This subsystem inserts a correction step **between**
//! gradient computation and the [`crate::trainer::OptimizerKind`] update,
//! shared by both engines (sim and threaded stay bit-identical under every
//! strategy — tests/integration_engines.rs):
//!
//! * [`CompensatorKind::None`] — the paper baseline: apply the raw stale
//!   gradient.
//! * [`CompensatorKind::DelayComp`] — DC-S3GD-style first-order delay
//!   compensation (Rigazzi et al., "DC-S3GD: Delay-Compensated Stale-
//!   Synchronous SGD", after Zheng et al.'s DC-ASGD): approximate the fresh
//!   gradient with `g + λ·g⊙g⊙(w_now − w_snapshot)`, using the diagonal
//!   outer-product surrogate for the Hessian in the Taylor expansion around
//!   the forward-time weight snapshot already carried in
//!   [`crate::staleness::Stash::params`].
//! * [`CompensatorKind::Accumulate`] — ADL-style gradient accumulation
//!   (Zhuang et al., "Accumulated Decoupled Learning"): average n
//!   micro-step gradients and apply the stale update once per n
//!   iterations, shrinking gradient variance under staleness.
//!
//! Every strategy owns **per-module state** (one [`Compensator`] box per
//! [`crate::pipeline::module_agent::ModuleAgent`]), corrects the agent's
//! workspace gradients **in place** (the steady-state loop is
//! allocation-free — tests/alloc_guard.rs), and is snapshotted into
//! checkpoints as [`CompensatorState`] so exact resume stays bit-identical.
//! The per-iteration correction magnitude is surfaced per module in
//! [`crate::session::IterEvent::correction`].

pub mod accumulate;
pub mod delay;

pub use accumulate::Accumulate;
pub use delay::DelayComp;

use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// Which gradient-correction strategy a run uses (config axis, CLI
/// `--compensate`, sweep axis). Parse mirror of
/// [`crate::trainer::OptimizerKind::parse`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CompensatorKind {
    /// Paper baseline: apply the raw stale gradient unchanged.
    None,
    /// DC-S3GD first-order correction `g + λ·g⊙g⊙(w_now − w_snapshot)`.
    DelayComp { lambda: f64 },
    /// ADL gradient accumulation: average `n` micro-steps, update once.
    Accumulate { n: usize },
}

impl CompensatorKind {
    /// Parse "none" | "dc:LAMBDA" | "accum:N" (case-insensitive,
    /// whitespace-tolerant around both the strategy and its parameter,
    /// like [`crate::trainer::OptimizerKind::parse`]). Bad parameters
    /// (dc λ < 0 or non-finite, accum n = 0) are rejected with a typed
    /// [`Error::Config`].
    pub fn parse(s: &str) -> Result<CompensatorKind> {
        let norm = s.trim().to_ascii_lowercase();
        let bad = || Error::Config(format!("bad compensator {s:?} (want none|dc:LAMBDA|accum:N)"));
        if norm == "none" {
            return Ok(CompensatorKind::None);
        }
        if let Some(v) = norm.strip_prefix("dc:") {
            let lambda: f64 = v.trim().parse().map_err(|_| bad())?;
            let kind = CompensatorKind::DelayComp { lambda };
            kind.validate()?;
            return Ok(kind);
        }
        if let Some(v) = norm.strip_prefix("accum:") {
            let n: usize = v.trim().parse().map_err(|_| bad())?;
            let kind = CompensatorKind::Accumulate { n };
            kind.validate()?;
            return Ok(kind);
        }
        Err(bad())
    }

    pub fn describe(&self) -> String {
        match self {
            CompensatorKind::None => "none".into(),
            CompensatorKind::DelayComp { lambda } => format!("dc:{lambda}"),
            CompensatorKind::Accumulate { n } => format!("accum:{n}"),
        }
    }

    /// Reject parameters no strategy can run with (directly-constructed
    /// configs bypass `parse`, so `ExperimentConfig::validate` calls this).
    pub fn validate(&self) -> Result<()> {
        match *self {
            CompensatorKind::None => Ok(()),
            CompensatorKind::DelayComp { lambda } => {
                if lambda.is_finite() && lambda >= 0.0 {
                    Ok(())
                } else {
                    Err(Error::Config(format!(
                        "dc lambda must be finite and >= 0, got {lambda}"
                    )))
                }
            }
            CompensatorKind::Accumulate { n } => {
                if n >= 1 {
                    Ok(())
                } else {
                    Err(Error::Config("accum n must be >= 1".into()))
                }
            }
        }
    }

    /// Instantiate the per-module strategy state.
    pub fn build(&self) -> Box<dyn Compensator> {
        match *self {
            CompensatorKind::None => Box::new(NoCompensation),
            CompensatorKind::DelayComp { lambda } => Box::new(DelayComp::new(lambda)),
            CompensatorKind::Accumulate { n } => Box::new(Accumulate::new(n)),
        }
    }
}

/// What the strategy decided for this iteration's update.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Compensated {
    /// Take one optimizer step with the (now corrected-in-place) workspace
    /// gradients. `correction_norm` is ‖g_eff − g_raw‖₂ over all of the
    /// module's parameter tensors (0 when nothing was corrected).
    Apply { correction_norm: f64 },
    /// Hold the update this iteration (mid-accumulation); the workspace
    /// gradients are left untouched and will be overwritten by the next
    /// backward.
    Hold,
}

/// Portable snapshot of a strategy's mutable state (full-resume
/// checkpoints; both engines produce and accept the same shape).
#[derive(Debug, Clone, Default)]
pub struct CompensatorState {
    /// accumulated gradient sums, per local layer (Accumulate)
    pub accum: Vec<(Tensor, Tensor)>,
    /// micro-steps accumulated so far (Accumulate)
    pub count: usize,
}

/// One module's gradient-correction strategy. Called once per scheduled
/// backward, between gradient computation and the optimizer step —
/// identically ordered in both engines, which is what keeps sim ≡ threaded
/// bit-identical under every strategy. Corrects the agent's workspace
/// gradient buffers **in place** — the steady-state loop moves and copies
/// nothing (tests/alloc_guard.rs).
pub trait Compensator: Send {
    /// Transform the raw stale gradient in `grads` in place. `now` is the
    /// module's current weights ŵ(t); `snapshot` is the forward-time
    /// weight snapshot the gradient was evaluated at (eq. (10): w(τ+k−1),
    /// from the stash).
    fn compensate(
        &mut self,
        grads: &mut [(Tensor, Tensor)],
        now: &[(Tensor, Tensor)],
        snapshot: &[(Tensor, Tensor)],
    ) -> Compensated;

    /// Snapshot mutable state for full-resume checkpoints (stateless
    /// strategies return the empty default).
    fn state(&self) -> CompensatorState {
        CompensatorState::default()
    }

    /// Restore state saved by [`Self::state`] (the empty default resets to
    /// the pre-first-step state).
    fn set_state(&mut self, _state: CompensatorState) {}
}

/// The paper baseline: pass the raw stale gradient through untouched.
#[derive(Debug, Clone, Copy, Default)]
pub struct NoCompensation;

impl Compensator for NoCompensation {
    fn compensate(
        &mut self,
        _grads: &mut [(Tensor, Tensor)],
        _now: &[(Tensor, Tensor)],
        _snapshot: &[(Tensor, Tensor)],
    ) -> Compensated {
        Compensated::Apply {
            correction_norm: 0.0,
        }
    }
}

/// Group-mean of per-module correction norms: sum over groups in
/// ascending-s order, then divide by S. Both engines reduce their
/// per-group observations through this one function, so the
/// [`crate::session::IterEvent::correction`] field stays bit-identical
/// between sim and threaded by construction.
pub fn group_mean_correction(k_modules: usize, per_group: &[Vec<f64>]) -> Vec<f64> {
    let mut mean = vec![0.0f64; k_modules];
    for group in per_group {
        debug_assert_eq!(group.len(), k_modules);
        for (k, c) in group.iter().enumerate() {
            mean[k] += c;
        }
    }
    let s = per_group.len().max(1) as f64;
    for c in mean.iter_mut() {
        *c /= s;
    }
    mean
}

#[cfg(test)]
pub(crate) fn test_grads(vals: &[f32]) -> Vec<(Tensor, Tensor)> {
    vals.iter()
        .map(|&v| {
            (
                Tensor::from_vec(&[2], vec![v, -v]).unwrap(),
                Tensor::from_vec(&[1], vec![v * 0.5]).unwrap(),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in ["none", "dc:0.04", "accum:2"] {
            let k = CompensatorKind::parse(s).unwrap();
            assert_eq!(CompensatorKind::parse(&k.describe()).unwrap(), k);
        }
    }

    #[test]
    fn parse_is_lenient_about_case_and_whitespace() {
        assert_eq!(CompensatorKind::parse(" None ").unwrap(), CompensatorKind::None);
        assert_eq!(
            CompensatorKind::parse("DC:0.04").unwrap(),
            CompensatorKind::DelayComp { lambda: 0.04 }
        );
        assert_eq!(
            CompensatorKind::parse(" Accum:3 ").unwrap(),
            CompensatorKind::Accumulate { n: 3 }
        );
    }

    #[test]
    fn parse_rejects_bad_parameters() {
        assert!(CompensatorKind::parse("dc").is_err());
        assert!(CompensatorKind::parse("dc:x").is_err());
        assert!(CompensatorKind::parse("dc:-0.1").is_err());
        assert!(CompensatorKind::parse("accum:0").is_err());
        assert!(CompensatorKind::parse("accum:1.5").is_err());
        assert!(CompensatorKind::parse("ema:0.9").is_err());
    }

    #[test]
    fn validate_catches_directly_constructed_bad_kinds() {
        assert!(CompensatorKind::DelayComp { lambda: f64::NAN }.validate().is_err());
        assert!(CompensatorKind::Accumulate { n: 0 }.validate().is_err());
        assert!(CompensatorKind::None.validate().is_ok());
    }

    #[test]
    fn group_mean_is_elementwise_over_groups() {
        let mean = group_mean_correction(2, &[vec![1.0, 0.0], vec![3.0, 2.0]]);
        assert_eq!(mean, vec![2.0, 1.0]);
        // no groups: zeros, not NaN
        assert_eq!(group_mean_correction(2, &[]), vec![0.0, 0.0]);
    }

    #[test]
    fn none_passes_raw_through_uncorrected() {
        let mut g = test_grads(&[1.0, 2.0]);
        let orig = test_grads(&[1.0, 2.0]);
        let w = test_grads(&[0.0, 0.0]);
        let mut c = CompensatorKind::None.build();
        match c.compensate(&mut g, &w, &w) {
            Compensated::Apply { correction_norm } => {
                assert_eq!(correction_norm, 0.0);
                for ((aw, ab), (bw, bb)) in g.iter().zip(&orig) {
                    assert_eq!(aw, bw);
                    assert_eq!(ab, bb);
                }
            }
            other => panic!("expected Apply, got {other:?}"),
        }
    }

    #[test]
    fn parse_tolerates_whitespace_around_parameters() {
        assert_eq!(
            CompensatorKind::parse("dc: 0.04").unwrap(),
            CompensatorKind::DelayComp { lambda: 0.04 }
        );
        assert_eq!(
            CompensatorKind::parse("ACCUM: 4 ").unwrap(),
            CompensatorKind::Accumulate { n: 4 }
        );
    }
}
