//! ADL-style gradient accumulation (Zhuang et al., "Accumulated Decoupled
//! Learning"): average n micro-step gradients before applying the stale
//! update.
//!
//! Each scheduled backward deposits its gradient into a running sum; every
//! n-th deposit emits the mean and the module takes one optimizer step.
//! The (n−1) intermediate iterations [`Compensated::Hold`] the update —
//! weights stay fixed, so the n gradients in a window are all evaluated
//! against the same update epoch, shrinking stale-gradient variance at the
//! cost of n× fewer (but n×-larger-batch) updates.
//!
//! n = 1 degenerates to the raw stale update (the `None` baseline) —
//! asserted bit-exactly in the tests below. The running sum and counter
//! are checkpointed via [`CompensatorState`] so exact resume stays
//! bit-identical mid-window.

use crate::compensate::{Compensated, Compensator, CompensatorState};
use crate::tensor::Tensor;

/// Per-module accumulation strategy: running (W, b) sums + a micro-step
/// counter.
#[derive(Debug, Clone)]
pub struct Accumulate {
    n: usize,
    sum: Vec<(Tensor, Tensor)>,
    count: usize,
}

impl Accumulate {
    pub fn new(n: usize) -> Accumulate {
        assert!(n >= 1, "accum n must be >= 1");
        Accumulate {
            n,
            sum: Vec::new(),
            count: 0,
        }
    }
}

impl Compensator for Accumulate {
    fn compensate(
        &mut self,
        grads: &mut [(Tensor, Tensor)],
        _now: &[(Tensor, Tensor)],
        _snapshot: &[(Tensor, Tensor)],
    ) -> Compensated {
        if self.sum.len() != grads.len() {
            // lazy one-time sizing; the buffers live for the module's whole
            // run (emits zero them in place rather than dropping them)
            self.sum = grads
                .iter()
                .map(|(w, b)| (Tensor::zeros(w.shape()), Tensor::zeros(b.shape())))
                .collect();
            self.count = 0;
        }
        for ((s_w, s_b), (g_w, g_b)) in self.sum.iter_mut().zip(grads.iter()) {
            s_w.axpy(1.0, g_w);
            s_b.axpy(1.0, g_b);
        }
        self.count += 1;
        if self.count < self.n {
            return Compensated::Hold;
        }
        // emit: write the window mean over the raw workspace gradient and
        // measure how far the applied gradient moved from this iteration's
        // raw one — one pass, in place, keeping the sum buffers
        let inv = 1.0 / self.n as f32;
        let mut sq = 0.0f64;
        for ((s_w, s_b), (g_w, g_b)) in self.sum.iter_mut().zip(grads.iter_mut()) {
            for (s, g) in s_w.data_mut().iter_mut().zip(g_w.data_mut()) {
                let m = *s * inv;
                let d = (m - *g) as f64;
                sq += d * d;
                *g = m;
                *s = 0.0;
            }
            for (s, g) in s_b.data_mut().iter_mut().zip(g_b.data_mut()) {
                let m = *s * inv;
                let d = (m - *g) as f64;
                sq += d * d;
                *g = m;
                *s = 0.0;
            }
        }
        self.count = 0;
        Compensated::Apply {
            correction_norm: sq.sqrt(),
        }
    }

    fn state(&self) -> CompensatorState {
        CompensatorState {
            accum: self.sum.clone(),
            count: self.count,
        }
    }

    fn set_state(&mut self, state: CompensatorState) {
        self.sum = state.accum;
        self.count = state.count;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compensate::test_grads;

    #[test]
    fn n1_is_bit_identical_to_none() {
        let g = test_grads(&[0.3, -1.2]);
        let w = test_grads(&[0.0, 0.0]);
        let mut a = Accumulate::new(1);
        for _ in 0..3 {
            let mut grads = g.clone();
            match a.compensate(&mut grads, &w, &w) {
                Compensated::Apply { correction_norm } => {
                    assert_eq!(correction_norm, 0.0);
                    for ((aw, ab), (bw, bb)) in grads.iter().zip(&g) {
                        assert_eq!(aw, bw);
                        assert_eq!(ab, bb);
                    }
                }
                other => panic!("expected Apply, got {other:?}"),
            }
        }
    }

    #[test]
    fn n2_holds_then_emits_the_mean() {
        let w = test_grads(&[0.0]);
        let g1 = test_grads(&[1.0]);
        let mut g2 = test_grads(&[3.0]);
        let mut a = Accumulate::new(2);
        assert!(matches!(
            a.compensate(&mut g1.clone(), &w, &w),
            Compensated::Hold
        ));
        match a.compensate(&mut g2, &w, &w) {
            Compensated::Apply { .. } => {
                // mean of W = [1, −1] and [3, −3], written over the input
                assert_eq!(g2[0].0.data(), &[2.0, -2.0]);
                assert_eq!(g2[0].1.data(), &[1.0]);
            }
            other => panic!("expected Apply, got {other:?}"),
        }
        // window resets: next deposit holds again
        assert!(matches!(
            a.compensate(&mut g1.clone(), &w, &w),
            Compensated::Hold
        ));
    }

    #[test]
    fn state_roundtrip_resumes_mid_window() {
        let w = test_grads(&[0.0]);
        let g1 = test_grads(&[1.0]);
        let g2 = test_grads(&[5.0]);
        let mut a = Accumulate::new(2);
        assert!(matches!(
            a.compensate(&mut g1.clone(), &w, &w),
            Compensated::Hold
        ));

        let saved = a.state();
        assert_eq!(saved.count, 1);
        let mut b = Accumulate::new(2);
        b.set_state(saved);

        let mut ga = g2.clone();
        let mut gb = g2.clone();
        assert!(matches!(
            a.compensate(&mut ga, &w, &w),
            Compensated::Apply { .. }
        ));
        assert!(matches!(
            b.compensate(&mut gb, &w, &w),
            Compensated::Apply { .. }
        ));
        assert_eq!(ga[0].0, gb[0].0);
        assert_eq!(ga[0].1, gb[0].1);
    }

    #[test]
    fn empty_state_resets_to_fresh() {
        let w = test_grads(&[0.0]);
        let g = test_grads(&[1.0]);
        let mut a = Accumulate::new(3);
        assert!(matches!(
            a.compensate(&mut g.clone(), &w, &w),
            Compensated::Hold
        ));
        a.set_state(CompensatorState::default());
        // counter back to zero: two more holds before an emit
        assert!(matches!(
            a.compensate(&mut g.clone(), &w, &w),
            Compensated::Hold
        ));
        assert!(matches!(
            a.compensate(&mut g.clone(), &w, &w),
            Compensated::Hold
        ));
        assert!(matches!(
            a.compensate(&mut g.clone(), &w, &w),
            Compensated::Apply { .. }
        ));
    }
}
