use std::collections::HashMap;

pub fn order_sensitive(map: &HashMap<u32, f64>) -> f64 {
    let mut total = 0.0;
    for (_, v) in map {
        total += v;
    }
    total
}
