#[steady_state]
pub fn kernel() -> usize {
    let scratch: Vec<f64> = Vec::new();
    let extra = vec![0.0f64; 4];
    scratch.len() + extra.len()
}

pub fn setup() -> Vec<f64> {
    Vec::new()
}
