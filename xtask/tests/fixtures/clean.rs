use std::collections::BTreeMap;

pub fn total(map: &BTreeMap<u32, f64>) -> f64 {
    let mut acc = 0.0;
    for (_, v) in map {
        acc += *v;
    }
    acc
}
