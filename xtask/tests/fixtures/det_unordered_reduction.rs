use std::collections::BTreeMap;

pub fn total(map: &BTreeMap<u32, f64>) -> f64 {
    map.values().copied().sum()
}
