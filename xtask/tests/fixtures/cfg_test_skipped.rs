pub fn fine() -> u32 {
    7
}

#[cfg(test)]
mod tests {
    #[test]
    fn uses_unwrap() {
        Some(1u32).unwrap();
    }
}
