pub fn risky(x: Option<u32>) -> u32 {
    x.unwrap()
}

pub fn also_risky(x: Result<u32, String>) -> u32 {
    x.expect("present")
}
