pub fn roll() -> u64 {
    let mut rng = thread_rng();
    rng.next_u64()
}
