pub fn explode(ok: bool) {
    if !ok {
        panic!("boom");
    }
}
