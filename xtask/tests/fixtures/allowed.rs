pub fn checked(x: Option<u32>) -> u32 {
    // sgs-lint: allow(rob-unwrap)
    x.unwrap()
}

pub fn checked_inline(x: Option<u32>) -> u32 {
    x.unwrap() // sgs-lint: allow(rob-unwrap)
}
