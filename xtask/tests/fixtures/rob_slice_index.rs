pub fn first(buf: &[u8]) -> u8 {
    buf[0]
}
