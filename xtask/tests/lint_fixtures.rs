//! Tests for the `sgs-lint` pass itself: every rule must fire on its
//! seeded-violation fixture with the right span, stay quiet on the clean
//! fixture, and honor `// sgs-lint: allow(...)` suppressions.

use std::fs;
use std::path::PathBuf;

use xtask::lint::{lint_source, FileOutcome, Rule};

fn fixture(name: &str) -> String {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name);
    fs::read_to_string(&path).unwrap_or_else(|e| panic!("fixture {name}: {e}"))
}

fn lint_fixture(rel: &str, name: &str) -> FileOutcome {
    lint_source(rel, &fixture(name)).unwrap_or_else(|e| panic!("fixture {name}: {e}"))
}

fn lines_for(out: &FileOutcome, rule: Rule) -> Vec<usize> {
    out.violations
        .iter()
        .filter(|v| v.rule == rule)
        .map(|v| v.line)
        .collect()
}

#[test]
fn det_hash_container_fires_with_span() {
    let out = lint_fixture("pipeline/fixture.rs", "det_hash_container.rs");
    let lines = lines_for(&out, Rule::DetHashContainer);
    assert!(lines.contains(&1), "use statement flagged: {lines:?}");
    assert!(lines.contains(&3), "type position flagged: {lines:?}");
}

#[test]
fn det_wall_clock_fires_with_span() {
    let out = lint_fixture("staleness/fixture.rs", "det_wall_clock.rs");
    assert_eq!(lines_for(&out, Rule::DetWallClock), vec![2]);
}

/// `det-wall-clock` is repo-wide — it fires even in modules outside the
/// deterministic family — and `obs/` is the only exempt module family.
#[test]
fn det_wall_clock_is_repo_wide_except_obs() {
    // benchkit is neither deterministic nor fallible, yet Instant still fires
    let out = lint_fixture("benchkit/fixture.rs", "det_wall_clock.rs");
    assert_eq!(lines_for(&out, Rule::DetWallClock), vec![2]);
    // cli too
    let out = lint_fixture("cli/commands.rs", "det_wall_clock.rs");
    assert_eq!(lines_for(&out, Rule::DetWallClock), vec![2]);
    // the obs clock gateway is the sole exemption
    let out = lint_fixture("obs/clock.rs", "det_wall_clock.rs");
    assert!(lines_for(&out, Rule::DetWallClock).is_empty(), "{:?}", out.violations);
    let out = lint_fixture("obs/timer.rs", "det_wall_clock.rs");
    assert!(lines_for(&out, Rule::DetWallClock).is_empty(), "{:?}", out.violations);
}

#[test]
fn det_ambient_rng_fires_with_span() {
    let out = lint_fixture("data/fixture.rs", "det_ambient_rng.rs");
    assert_eq!(lines_for(&out, Rule::DetAmbientRng), vec![2]);
}

#[test]
fn det_unordered_reduction_fires_with_span() {
    let out = lint_fixture("consensus/fixture.rs", "det_unordered_reduction.rs");
    assert_eq!(lines_for(&out, Rule::DetUnorderedReduction), vec![4]);
}

#[test]
fn rob_unwrap_fires_on_unwrap_and_expect() {
    let out = lint_fixture("net/fixture.rs", "rob_unwrap.rs");
    assert_eq!(lines_for(&out, Rule::RobUnwrap), vec![2, 6]);
}

#[test]
fn rob_panic_fires_with_span() {
    let out = lint_fixture("session/fixture.rs", "rob_panic.rs");
    assert_eq!(lines_for(&out, Rule::RobPanic), vec![3]);
}

#[test]
fn rob_slice_index_fires_only_in_scoped_files() {
    let out = lint_fixture("net/wire.rs", "rob_slice_index.rs");
    assert_eq!(lines_for(&out, Rule::RobSliceIndex), vec![2]);
    // The same source outside the decoder files is exempt.
    let elsewhere = lint_fixture("net/dist.rs", "rob_slice_index.rs");
    assert!(lines_for(&elsewhere, Rule::RobSliceIndex).is_empty());
}

#[test]
fn hot_alloc_fires_only_inside_steady_state_fns() {
    // `runtime/` is in neither rule family, so only hot-alloc can fire.
    let out = lint_fixture("runtime/fixture.rs", "hot_alloc.rs");
    assert_eq!(lines_for(&out, Rule::HotAlloc), vec![3, 4]);
    assert_eq!(out.violations.len(), 2, "un-annotated fn stays clean");
}

#[test]
fn clean_fixture_has_no_violations() {
    let out = lint_fixture("pipeline/fixture.rs", "clean.rs");
    assert!(out.violations.is_empty(), "{:?}", out.violations);
    assert_eq!(out.allowed, 0);
}

#[test]
fn allow_comment_suppresses_same_line_and_line_above() {
    let out = lint_fixture("net/fixture.rs", "allowed.rs");
    assert!(out.violations.is_empty(), "{:?}", out.violations);
    assert_eq!(out.allowed, 2);
}

#[test]
fn cfg_test_items_are_skipped() {
    let out = lint_fixture("net/fixture.rs", "cfg_test_skipped.rs");
    assert!(out.violations.is_empty(), "{:?}", out.violations);
}

#[test]
fn rules_do_not_fire_outside_their_module_family() {
    // A HashMap in a non-deterministic module is fine.
    let out = lint_fixture("metrics/fixture.rs", "det_hash_container.rs");
    assert!(out.violations.is_empty(), "{:?}", out.violations);
    // An unwrap in a non-fallible module is fine.
    let out = lint_fixture("benchkit/fixture.rs", "rob_unwrap.rs");
    assert!(out.violations.is_empty(), "{:?}", out.violations);
}

#[test]
fn repo_source_tree_is_lint_clean() {
    // The acceptance bar: `cargo run -p xtask -- lint` exits 0 on the
    // repo. Running it here too makes `cargo test -p xtask` self-contained.
    let src_root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../rust/src");
    let report = xtask::lint::lint_tree(&src_root);
    assert!(report.files_scanned > 0, "rust/src not found from xtask/");
    assert!(report.errors.is_empty(), "{:?}", report.errors);
    let rendered: Vec<String> = report
        .violations
        .iter()
        .map(|v| format!("{}:{}: [{}] {}", v.file, v.line, v.rule, v.message))
        .collect();
    assert!(rendered.is_empty(), "lint violations:\n{}", rendered.join("\n"));
}
