//! A minimal JSON reader — just enough to load committed `BENCH_*.json`
//! baselines without adding a runtime dependency to the workspace.

/// A parsed JSON value. Numbers are kept as `f64` (the baselines only
/// carry timings and small integers).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let value = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing bytes at offset {}", p.pos));
    }
    Ok(value)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self
            .bytes
            .get(self.pos)
            .is_some_and(|b| matches!(b, b' ' | b'\t' | b'\n' | b'\r'))
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8, String> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| "unexpected end of input".to_string())
    }

    fn expect(&mut self, byte: u8) -> Result<(), String> {
        if self.peek()? == byte {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected `{}` at offset {}", byte as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, String> {
        let end = self.pos + word.len();
        if self.bytes.get(self.pos..end) == Some(word.as_bytes()) {
            self.pos = end;
            Ok(value)
        } else {
            Err(format!("invalid literal at offset {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while self
            .bytes
            .get(self.pos)
            .is_some_and(|b| matches!(b, b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| format!("bad number at offset {start}"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number `{text}` at offset {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek()? {
                b'"' => {
                    self.pos += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.pos += 1;
                    let esc = self.peek()?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let end = self.pos + 4;
                            let hex = self
                                .bytes
                                .get(self.pos..end)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or_else(|| "truncated \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| format!("bad \\u escape `{hex}`"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos = end;
                        }
                        other => return Err(format!("bad escape `\\{}`", other as char)),
                    }
                }
                _ => {
                    // Consume one UTF-8 scalar from the raw bytes.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| format!("invalid utf-8 at offset {}", self.pos))?;
                    let c = rest
                        .chars()
                        .next()
                        .ok_or_else(|| "unexpected end of string".to_string())?;
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected `,` or `]`, got `{}`", other as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            pairs.push((key, value));
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                other => return Err(format!("expected `,` or `}}`, got `{}`", other as char)),
            }
        }
    }
}
