//! `sgs-lint`: the repo's custom invariant-enforcing static-analysis pass.
//!
//! A syn-based AST walk over `rust/src/**` enforcing three rule families:
//!
//! - **determinism** (`det-*`) — modules on the bitwise-reproducibility
//!   path (sim ≡ threaded ≡ dist) must not consult hash-ordered
//!   containers, ambient RNG, or reduce floats in an unspecified order.
//!   `det-wall-clock` is repo-wide: `Instant`/`SystemTime` may only be
//!   named inside the `obs/` module family (the crate's clock gateway).
//! - **robustness** (`rob-*`) — fallible runtime paths must surface
//!   failures through the typed `Error` enum, never `unwrap`/`panic!`;
//!   the untrusted-input decoders must bounds-check instead of indexing.
//! - **hot-path allocation** (`hot-alloc`) — functions annotated
//!   `#[sgs::steady_state]` must not allocate.
//!
//! Suppress a finding with `// sgs-lint: allow(<rule>)` on the same line
//! or the line directly above. `allow(all)` suppresses every rule.
//! Test-only code (`#[cfg(test)]`) is skipped entirely.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use proc_macro2::Span;
use quote::ToTokens;
use syn::spanned::Spanned;
use syn::visit::{self, Visit};

/// Modules that must stay bitwise deterministic (same schedule, same
/// floats, run to run). Matched as `name/` prefixes or `name.rs` files
/// relative to `rust/src/`.
const DETERMINISTIC: &[&str] = &[
    "nn",
    "tensor",
    "pipeline",
    "trainer",
    "checkpoint",
    "data",
    "staleness",
    "compensate",
    "consensus",
    "graph",
    "simclock",
    "serve",
];

/// Modules whose runtime paths must propagate typed errors, never panic:
/// a lost peer or a corrupt frame has to surface as `Err`, not a crash.
const FALLIBLE: &[&str] = &["net", "pipeline", "trainer", "session", "checkpoint", "serve"];

/// Files where direct slice indexing is forbidden outright: these decode
/// untrusted bytes, so every access must be a checked `.get(..)`. The
/// rest of `net/` indexes invariant-backed local state and is exempt.
const INDEX_SCOPED: &[&str] = &["net/wire.rs", "net/transport.rs"];

/// A lint rule. [`Rule::name`] is the stable identifier used in reports
/// and in `// sgs-lint: allow(<name>)` suppressions.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Rule {
    DetHashContainer,
    DetWallClock,
    DetAmbientRng,
    DetUnorderedReduction,
    RobUnwrap,
    RobPanic,
    RobSliceIndex,
    HotAlloc,
}

impl Rule {
    pub const ALL: &'static [Rule] = &[
        Rule::DetHashContainer,
        Rule::DetWallClock,
        Rule::DetAmbientRng,
        Rule::DetUnorderedReduction,
        Rule::RobUnwrap,
        Rule::RobPanic,
        Rule::RobSliceIndex,
        Rule::HotAlloc,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Rule::DetHashContainer => "det-hash-container",
            Rule::DetWallClock => "det-wall-clock",
            Rule::DetAmbientRng => "det-ambient-rng",
            Rule::DetUnorderedReduction => "det-unordered-reduction",
            Rule::RobUnwrap => "rob-unwrap",
            Rule::RobPanic => "rob-panic",
            Rule::RobSliceIndex => "rob-slice-index",
            Rule::HotAlloc => "hot-alloc",
        }
    }
}

impl fmt::Display for Rule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One finding. `line` is 1-based, `column` is 0-based (both from the
/// proc-macro2 span of the offending token).
#[derive(Clone, Debug)]
pub struct Violation {
    pub rule: Rule,
    pub file: String,
    pub line: usize,
    pub column: usize,
    pub message: String,
}

/// Result of linting a single file.
pub struct FileOutcome {
    pub violations: Vec<Violation>,
    pub allowed: usize,
}

/// Result of linting a whole source tree.
pub struct Report {
    pub files_scanned: usize,
    pub allowed: usize,
    pub violations: Vec<Violation>,
    pub errors: Vec<String>,
}

/// Lint one file's source text. `rel_path` is the path relative to
/// `rust/src/` (forward slashes) — it decides which rule families apply.
pub fn lint_source(rel_path: &str, source: &str) -> Result<FileOutcome, String> {
    let parsed = syn::parse_file(source)
        .map_err(|e| format!("{rel_path}:{}: parse error: {e}", e.span().start().line))?;
    let ctx = FileCtx::classify(rel_path);
    let mut visitor = LintVisitor {
        ctx: &ctx,
        raw: Vec::new(),
        steady_depth: 0,
    };
    visitor.visit_file(&parsed);
    let lines: Vec<&str> = source.lines().collect();
    let mut violations = Vec::new();
    let mut allowed = 0usize;
    for v in visitor.raw {
        if is_allowed(&lines, v.line, v.rule) {
            allowed += 1;
        } else {
            violations.push(v);
        }
    }
    violations.sort_by(|a, b| (a.line, a.column).cmp(&(b.line, b.column)));
    Ok(FileOutcome { violations, allowed })
}

/// Lint every `.rs` file under `src_root` (normally `rust/src`).
pub fn lint_tree(src_root: &Path) -> Report {
    let mut files = Vec::new();
    collect_rs(src_root, &mut files);
    files.sort();
    let mut report = Report {
        files_scanned: 0,
        allowed: 0,
        violations: Vec::new(),
        errors: Vec::new(),
    };
    for path in files {
        let rel = path
            .strip_prefix(src_root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        match fs::read_to_string(&path) {
            Ok(text) => match lint_source(&rel, &text) {
                Ok(out) => {
                    report.files_scanned += 1;
                    report.allowed += out.allowed;
                    report.violations.extend(out.violations);
                }
                Err(e) => report.errors.push(e),
            },
            Err(e) => report.errors.push(format!("{}: {e}", path.display())),
        }
    }
    report
}

/// Render the machine-readable JSON report (schema `sgs-lint-report/v1`).
pub fn report_json(report: &Report) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"schema\": \"sgs-lint-report/v1\",\n");
    s.push_str("  \"root\": \"rust/src\",\n");
    s.push_str(&format!("  \"files_scanned\": {},\n", report.files_scanned));
    s.push_str(&format!("  \"allowed_suppressions\": {},\n", report.allowed));
    s.push_str("  \"errors\": [");
    for (i, e) in report.errors.iter().enumerate() {
        if i > 0 {
            s.push_str(", ");
        }
        s.push_str(&format!("\"{}\"", json_escape(e)));
    }
    s.push_str("],\n");
    s.push_str("  \"violations\": [");
    for (i, v) in report.violations.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str("\n    {");
        s.push_str(&format!("\"rule\": \"{}\", ", v.rule));
        s.push_str(&format!("\"file\": \"{}\", ", json_escape(&v.file)));
        s.push_str(&format!("\"line\": {}, ", v.line));
        s.push_str(&format!("\"column\": {}, ", v.column));
        s.push_str(&format!("\"message\": \"{}\"}}", json_escape(&v.message)));
    }
    if !report.violations.is_empty() {
        s.push_str("\n  ");
    }
    s.push_str("]\n}\n");
    s
}

fn json_escape(text: &str) -> String {
    let mut out = String::with_capacity(text.len());
    for c in text.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else {
        return;
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

/// The only module family allowed to name `Instant`/`SystemTime`: the
/// observability clock gateway. Everything else — deterministic or not —
/// must read real time through `obs::WallClock` / `obs::Deadline` /
/// `obs::timer`, so wall-clock access stays auditable in one place.
const WALL_CLOCK_EXEMPT: &[&str] = &["obs"];

struct FileCtx {
    rel: String,
    deterministic: bool,
    fallible: bool,
    index_scoped: bool,
    wall_clock_exempt: bool,
}

impl FileCtx {
    fn classify(rel_path: &str) -> FileCtx {
        let rel = rel_path.replace('\\', "/");
        let in_family = |families: &[&str]| {
            families
                .iter()
                .any(|m| rel.starts_with(&format!("{m}/")) || rel == format!("{m}.rs"))
        };
        let deterministic = in_family(DETERMINISTIC);
        let fallible = in_family(FALLIBLE);
        let index_scoped = INDEX_SCOPED.contains(&rel.as_str());
        let wall_clock_exempt = in_family(WALL_CLOCK_EXEMPT);
        FileCtx {
            rel,
            deterministic,
            fallible,
            index_scoped,
            wall_clock_exempt,
        }
    }
}

/// `// sgs-lint: allow(rule-a, rule-b)` on the violation line or the line
/// directly above suppresses the finding.
fn is_allowed(lines: &[&str], line: usize, rule: Rule) -> bool {
    let check = |idx: usize| lines.get(idx).map(|l| line_allows(l, rule)).unwrap_or(false);
    // `line` is 1-based; check it and the line above.
    check(line.wrapping_sub(1)) || (line >= 2 && check(line - 2))
}

fn line_allows(line: &str, rule: Rule) -> bool {
    let Some(pos) = line.find("sgs-lint: allow(") else {
        return false;
    };
    let rest = &line[pos + "sgs-lint: allow(".len()..];
    let Some(end) = rest.find(')') else {
        return false;
    };
    rest[..end]
        .split(',')
        .map(str::trim)
        .any(|r| r == rule.name() || r == "all")
}

fn is_cfg_test(attrs: &[syn::Attribute]) -> bool {
    attrs.iter().any(|a| {
        a.path().is_ident("cfg")
            && a.meta
                .require_list()
                .map(|l| l.tokens.to_string() == "test")
                .unwrap_or(false)
    })
}

fn is_steady_state(attrs: &[syn::Attribute]) -> bool {
    attrs.iter().any(|a| {
        a.path()
            .segments
            .last()
            .map(|s| s.ident == "steady_state")
            .unwrap_or(false)
    })
}

/// True when a `sum`/`product`/`fold` receiver chain bottoms out in
/// `.keys()` / `.values()`, i.e. an iteration order the container — not
/// the code — decides. Pass-through adapters are chased.
fn reduction_over_keyed_iter(receiver: &syn::Expr) -> bool {
    let mut cur = receiver;
    loop {
        match cur {
            syn::Expr::MethodCall(mc) => {
                let method = mc.method.to_string();
                match method.as_str() {
                    "keys" | "values" | "values_mut" => return true,
                    "map" | "copied" | "cloned" | "filter" | "iter" | "iter_mut" | "into_iter" => {
                        cur = &mc.receiver;
                    }
                    _ => return false,
                }
            }
            syn::Expr::Paren(p) => cur = &p.expr,
            _ => return false,
        }
    }
}

/// Allocating `Type::method` constructors forbidden in steady-state fns.
fn is_alloc_ctor(segments: &[String]) -> bool {
    let n = segments.len();
    if n < 2 {
        return false;
    }
    let ty = segments[n - 2].as_str();
    let method = segments[n - 1].as_str();
    let alloc_ty = matches!(
        ty,
        "Vec" | "VecDeque" | "Box" | "String" | "BTreeMap" | "BTreeSet" | "HashMap" | "HashSet"
    );
    alloc_ty && matches!(method, "new" | "with_capacity" | "from")
}

struct LintVisitor<'a> {
    ctx: &'a FileCtx,
    raw: Vec<Violation>,
    steady_depth: usize,
}

impl LintVisitor<'_> {
    fn flag(&mut self, rule: Rule, span: Span, message: String) {
        let start = span.start();
        self.raw.push(Violation {
            rule,
            file: self.ctx.rel.clone(),
            line: start.line,
            column: start.column,
            message,
        });
    }
}

impl<'ast> Visit<'ast> for LintVisitor<'_> {
    fn visit_item_mod(&mut self, node: &'ast syn::ItemMod) {
        if is_cfg_test(&node.attrs) {
            return;
        }
        visit::visit_item_mod(self, node);
    }

    fn visit_item_impl(&mut self, node: &'ast syn::ItemImpl) {
        if is_cfg_test(&node.attrs) {
            return;
        }
        visit::visit_item_impl(self, node);
    }

    fn visit_item_fn(&mut self, node: &'ast syn::ItemFn) {
        if is_cfg_test(&node.attrs) {
            return;
        }
        let steady = is_steady_state(&node.attrs) as usize;
        self.steady_depth += steady;
        visit::visit_item_fn(self, node);
        self.steady_depth -= steady;
    }

    fn visit_impl_item_fn(&mut self, node: &'ast syn::ImplItemFn) {
        if is_cfg_test(&node.attrs) {
            return;
        }
        let steady = is_steady_state(&node.attrs) as usize;
        self.steady_depth += steady;
        visit::visit_impl_item_fn(self, node);
        self.steady_depth -= steady;
    }

    fn visit_trait_item_fn(&mut self, node: &'ast syn::TraitItemFn) {
        if is_cfg_test(&node.attrs) {
            return;
        }
        let steady = is_steady_state(&node.attrs) as usize;
        self.steady_depth += steady;
        visit::visit_trait_item_fn(self, node);
        self.steady_depth -= steady;
    }

    fn visit_ident(&mut self, node: &'ast proc_macro2::Ident) {
        let name = node.to_string();
        // Wall-clock access is repo-wide, not just deterministic modules:
        // `obs/` is the single gateway to real time.
        if matches!(name.as_str(), "Instant" | "SystemTime") && !self.ctx.wall_clock_exempt {
            self.flag(
                Rule::DetWallClock,
                node.span(),
                format!(
                    "`{name}` outside the `obs/` clock gateway — use obs::WallClock, \
                     obs::Deadline, or obs::timer"
                ),
            );
        }
        if !self.ctx.deterministic {
            return;
        }
        match name.as_str() {
            "HashMap" | "HashSet" | "RandomState" => self.flag(
                Rule::DetHashContainer,
                node.span(),
                format!("`{name}` in deterministic module — use BTreeMap/BTreeSet or a dense Vec"),
            ),
            "thread_rng" | "from_entropy" => self.flag(
                Rule::DetAmbientRng,
                node.span(),
                format!("`{name}` in deterministic module — randomness must flow from seeded Pcg32"),
            ),
            _ => {}
        }
    }

    fn visit_expr_method_call(&mut self, node: &'ast syn::ExprMethodCall) {
        let method = node.method.to_string();
        if self.ctx.fallible && (method == "unwrap" || method == "expect") {
            self.flag(
                Rule::RobUnwrap,
                node.method.span(),
                format!("`.{method}()` on a fallible runtime path — propagate a typed `Error`"),
            );
        }
        if self.ctx.deterministic
            && matches!(method.as_str(), "sum" | "product" | "fold")
            && reduction_over_keyed_iter(&node.receiver)
        {
            self.flag(
                Rule::DetUnorderedReduction,
                node.method.span(),
                format!(
                    "float `.{method}()` over `.keys()`/`.values()` — fix the iteration order \
                     (order-stable container) or allow-list with a proof"
                ),
            );
        }
        if self.steady_depth > 0
            && matches!(
                method.as_str(),
                "to_vec" | "to_string" | "to_owned" | "clone" | "collect"
            )
        {
            self.flag(
                Rule::HotAlloc,
                node.method.span(),
                format!("allocating `.{method}()` inside a #[steady_state] fn"),
            );
        }
        visit::visit_expr_method_call(self, node);
    }

    fn visit_expr_call(&mut self, node: &'ast syn::ExprCall) {
        if self.steady_depth > 0 {
            if let syn::Expr::Path(p) = &*node.func {
                let segments: Vec<String> =
                    p.path.segments.iter().map(|s| s.ident.to_string()).collect();
                if is_alloc_ctor(&segments) {
                    self.flag(
                        Rule::HotAlloc,
                        node.func.span(),
                        format!(
                            "allocating call `{}` inside a #[steady_state] fn",
                            p.path.to_token_stream()
                        ),
                    );
                }
            }
        }
        visit::visit_expr_call(self, node);
    }

    fn visit_expr_index(&mut self, node: &'ast syn::ExprIndex) {
        if self.ctx.index_scoped {
            self.flag(
                Rule::RobSliceIndex,
                node.span(),
                "direct index in an untrusted-input decoder — use `.get(..)` and surface \
                 `Error::Net`"
                    .to_string(),
            );
        }
        visit::visit_expr_index(self, node);
    }

    fn visit_macro(&mut self, node: &'ast syn::Macro) {
        let name = node
            .path
            .segments
            .last()
            .map(|s| s.ident.to_string())
            .unwrap_or_default();
        if self.ctx.fallible && matches!(name.as_str(), "panic" | "todo" | "unimplemented") {
            self.flag(
                Rule::RobPanic,
                node.path.span(),
                format!("`{name}!` on a fallible runtime path — return `Err(Error::…)` instead"),
            );
        }
        if self.steady_depth > 0 && matches!(name.as_str(), "vec" | "format") {
            self.flag(
                Rule::HotAlloc,
                node.path.span(),
                format!("allocating `{name}!` inside a #[steady_state] fn"),
            );
        }
        visit::visit_macro(self, node);
    }
}
