//! Repo automation (the `cargo xtask` pattern — build-time only, never
//! part of the shipped library):
//!
//! - `cargo run -p xtask -- lint` runs the `sgs-lint` invariant pass over
//!   `rust/src/**` (see `xtask/src/lint.rs` and the README section
//!   "Invariants & static analysis").
//! - `cargo run -p xtask -- bench-summary` folds `bench_out/*.csv` smoke
//!   results into the `BENCH_*.json` perf-trajectory format and diffs
//!   against a committed baseline.

use std::fs;
use std::path::PathBuf;
use std::process::ExitCode;

use xtask::{bench, lint};

const USAGE: &str = "\
usage:
  cargo run -p xtask -- lint [--root DIR] [--json PATH]
  cargo run -p xtask -- bench-summary [--bench-dir DIR] [--baseline PATH] [--out PATH]
                                      [--trace PATH (sgs trace-report --json output)]
                                      [--check (fail on >25% hot-path regressions vs a
                                       measured baseline)]
";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => cmd_lint(&args[1..]),
        Some("bench-summary") => cmd_bench(&args[1..]),
        _ => {
            eprint!("{USAGE}");
            ExitCode::from(2)
        }
    }
}

fn cmd_lint(args: &[String]) -> ExitCode {
    let root = match flag_value(args, "--root") {
        Ok(v) => v.unwrap_or_else(|| PathBuf::from(".")),
        Err(e) => return fail(&e),
    };
    let json_out = match flag_value(args, "--json") {
        Ok(v) => v,
        Err(e) => return fail(&e),
    };
    let src_root = root.join("rust").join("src");
    let report = lint::lint_tree(&src_root);
    if report.files_scanned == 0 {
        return fail(&format!(
            "no .rs files under {} — run from the repo root or pass --root",
            src_root.display()
        ));
    }
    for err in &report.errors {
        eprintln!("sgs-lint: error: {err}");
    }
    for v in &report.violations {
        eprintln!(
            "rust/src/{}:{}:{}: [{}] {}",
            v.file,
            v.line,
            v.column + 1,
            v.rule,
            v.message
        );
    }
    if let Some(path) = json_out {
        if let Err(e) = fs::write(&path, lint::report_json(&report)) {
            return fail(&format!("writing {}: {e}", path.display()));
        }
        println!("sgs-lint: report written to {}", path.display());
    }
    println!(
        "sgs-lint: {} files scanned, {} violations, {} suppressed",
        report.files_scanned,
        report.violations.len(),
        report.allowed
    );
    if report.violations.is_empty() && report.errors.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn cmd_bench(args: &[String]) -> ExitCode {
    let bench_dir = match flag_value(args, "--bench-dir") {
        Ok(v) => v.unwrap_or_else(|| PathBuf::from("bench_out")),
        Err(e) => return fail(&e),
    };
    let baseline = match flag_value(args, "--baseline") {
        Ok(v) => v,
        Err(e) => return fail(&e),
    };
    let out = match flag_value(args, "--out") {
        Ok(v) => v,
        Err(e) => return fail(&e),
    };
    let trace = match flag_value(args, "--trace") {
        Ok(v) => v,
        Err(e) => return fail(&e),
    };
    let check = args.iter().any(|a| a == "--check");
    match bench::run(&bench_dir, baseline.as_deref(), out.as_deref(), trace.as_deref(), check) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => fail(&e),
    }
}

fn flag_value(args: &[String], name: &str) -> Result<Option<PathBuf>, String> {
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        if arg == name {
            return match it.next() {
                Some(v) => Ok(Some(PathBuf::from(v))),
                None => Err(format!("{name} needs a value")),
            };
        }
    }
    Ok(None)
}

fn fail(msg: &str) -> ExitCode {
    eprintln!("xtask: {msg}");
    ExitCode::FAILURE
}
