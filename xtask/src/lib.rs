//! Library surface of the repo's automation tool, exposed so
//! `xtask/tests/` can drive the lint pass directly against fixture
//! sources. The binary (`cargo run -p xtask -- <cmd>`) is a thin wrapper
//! over these modules.

pub mod bench;
pub mod json;
pub mod lint;
