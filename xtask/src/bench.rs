//! `bench-summary`: fold `bench_out/*.csv` smoke results into the
//! `BENCH_<n>.json` perf-trajectory format and diff the hot-path
//! timings against a committed baseline — report-only by default,
//! failing on >25% regressions with `--check` (armed only once the
//! baseline carries `measured: true` numbers).

use std::fs;
use std::path::Path;

use crate::json::{parse, Json};

/// A parsed CSV: header row plus data rows, all fields as strings.
pub struct Csv {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

/// Read the two smoke CSVs from `bench_dir`, optionally ingest a
/// `sgs trace-report --json` document, write/print the JSON summary, and
/// diff hot-path means against `baseline` when it carries measured
/// numbers. By default the diff is report-only (perf drift is reported,
/// not gated, because CI runner timing is noisy); with `check` the run
/// fails when any hot-path mean regressed more than 25% against a
/// `measured: true` baseline. A placeholder baseline never fails.
pub fn run(
    bench_dir: &Path,
    baseline: Option<&Path>,
    out: Option<&Path>,
    trace: Option<&Path>,
    check: bool,
) -> Result<(), String> {
    let hot = read_csv(&bench_dir.join("hot_path.csv"))?;
    let ablation = read_csv(&bench_dir.join("ablation_compensate.csv"))?;
    let comm = read_csv(&bench_dir.join("comm_volume.csv"))?;
    let serve = read_csv(&bench_dir.join("serve_qps.csv"))?;
    let trace_report = match trace {
        Some(path) => Some(read_trace_report(path)?),
        None => None,
    };
    let measured = hot.is_some() || ablation.is_some() || comm.is_some() || serve.is_some();
    let summary = summary_json(
        hot.as_ref(),
        ablation.as_ref(),
        comm.as_ref(),
        serve.as_ref(),
        measured,
        trace_report.as_deref(),
    );
    match out {
        Some(path) => {
            fs::write(path, &summary).map_err(|e| format!("writing {}: {e}", path.display()))?;
            println!("bench-summary: wrote {}", path.display());
        }
        None => print!("{summary}"),
    }
    if let Some(base) = baseline {
        let regressions = diff_against(base, hot.as_ref())?;
        if check && !regressions.is_empty() {
            return Err(format!(
                "bench-summary --check: {} hot-path regression(s) over 25%: {}",
                regressions.len(),
                regressions.join(", ")
            ));
        }
    }
    Ok(())
}

fn read_csv(path: &Path) -> Result<Option<Csv>, String> {
    if !path.exists() {
        println!("bench-summary: {} missing, skipping", path.display());
        return Ok(None);
    }
    let text = fs::read_to_string(path).map_err(|e| format!("reading {}: {e}", path.display()))?;
    let mut lines = text.lines().filter(|l| !l.trim().is_empty());
    let Some(header_line) = lines.next() else {
        return Ok(None);
    };
    let header: Vec<String> = header_line.split(',').map(|s| s.trim().to_string()).collect();
    let mut rows = Vec::new();
    for line in lines {
        let row: Vec<String> = line.split(',').map(|s| s.trim().to_string()).collect();
        if row.len() != header.len() {
            return Err(format!("{}: ragged row `{line}`", path.display()));
        }
        rows.push(row);
    }
    Ok(Some(Csv { header, rows }))
}

/// Load and sanity-check a `sgs trace-report --json` document, returning
/// its (compact, validated) JSON text for embedding.
fn read_trace_report(path: &Path) -> Result<String, String> {
    let text = fs::read_to_string(path).map_err(|e| format!("reading {}: {e}", path.display()))?;
    let doc = parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
    match doc.get("schema").and_then(Json::as_str) {
        Some("sgs-trace-report/v1") => {}
        Some(other) => {
            return Err(format!(
                "{}: unexpected schema {other:?} (want sgs-trace-report/v1 from \
                 `sgs trace-report FILE --json`)",
                path.display()
            ))
        }
        None => {
            return Err(format!(
                "{}: missing \"schema\" key — pass the output of `sgs trace-report FILE --json`",
                path.display()
            ))
        }
    }
    Ok(text.trim().to_string())
}

fn summary_json(
    hot: Option<&Csv>,
    ablation: Option<&Csv>,
    comm: Option<&Csv>,
    serve: Option<&Csv>,
    measured: bool,
    trace_report: Option<&str>,
) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"schema\": \"sgs-bench/v1\",\n");
    s.push_str("  \"issue\": 10,\n");
    s.push_str(&format!("  \"measured\": {measured},\n"));
    s.push_str("  \"hot_path\": ");
    s.push_str(&csv_json(hot));
    s.push_str(",\n  \"ablation_compensate\": ");
    s.push_str(&csv_json(ablation));
    s.push_str(",\n  \"comm_volume\": ");
    s.push_str(&csv_json(comm));
    s.push_str(",\n  \"serve_qps\": ");
    s.push_str(&csv_json(serve));
    s.push_str(",\n  \"trace_report\": ");
    s.push_str(trace_report.unwrap_or("null"));
    s.push_str("\n}\n");
    s
}

/// Render CSV rows as a JSON array of objects keyed by the header.
/// Fields that parse as finite numbers are emitted bare, others quoted.
fn csv_json(csv: Option<&Csv>) -> String {
    let Some(csv) = csv else {
        return "[]".to_string();
    };
    let mut s = String::from("[");
    for (i, row) in csv.rows.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str("\n    {");
        for (j, (key, value)) in csv.header.iter().zip(row.iter()).enumerate() {
            if j > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!("\"{key}\": "));
            match value.parse::<f64>() {
                Ok(n) if n.is_finite() => s.push_str(value),
                _ => s.push_str(&format!("\"{value}\"")),
            }
        }
        s.push('}');
    }
    if !csv.rows.is_empty() {
        s.push_str("\n  ");
    }
    s.push(']');
    s
}

/// Diff hot-path means against the baseline, returning the names of
/// benches that regressed more than 25% (empty when the baseline is a
/// placeholder or nothing regressed).
fn diff_against(baseline: &Path, hot: Option<&Csv>) -> Result<Vec<String>, String> {
    let text =
        fs::read_to_string(baseline).map_err(|e| format!("reading {}: {e}", baseline.display()))?;
    let base = parse(&text).map_err(|e| format!("{}: {e}", baseline.display()))?;
    if base.get("measured").and_then(Json::as_bool) != Some(true) {
        println!(
            "bench-summary: baseline {} has no measured numbers yet; recording only",
            baseline.display()
        );
        return Ok(Vec::new());
    }
    let Some(hot) = hot else {
        println!("bench-summary: no hot_path.csv to diff against the baseline");
        return Ok(Vec::new());
    };
    let empty = Vec::new();
    let entries = match base.get("hot_path") {
        Some(Json::Arr(items)) => items,
        _ => &empty,
    };
    let mut regressions = Vec::new();
    for row in &hot.rows {
        let (Some(name), Some(mean_text)) = (row.first(), row.get(1)) else {
            continue;
        };
        let mean: f64 = mean_text.parse().unwrap_or(f64::NAN);
        let base_mean = entries.iter().find_map(|e| {
            let n = e.get("bench").and_then(Json::as_str)?;
            if n == name {
                e.get("mean_s").and_then(Json::as_f64)
            } else {
                None
            }
        });
        match base_mean {
            Some(b) if b > 0.0 && mean.is_finite() => {
                let pct = (mean - b) / b * 100.0;
                let tag = if pct > 25.0 { "  <-- regression?" } else { "" };
                println!(
                    "bench-summary: {name}: {mean:.6}s vs baseline {b:.6}s ({pct:+.1}%){tag}"
                );
                if pct > 25.0 {
                    regressions.push(format!("{name} ({pct:+.1}%)"));
                }
            }
            _ => println!("bench-summary: {name}: {mean:.6}s (no baseline entry)"),
        }
    }
    Ok(regressions)
}
