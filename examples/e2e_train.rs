//! END-TO-END driver: proves the full three-layer stack composes.
//!
//! Pallas kernels (L1) → JAX per-layer graphs (L2) → AOT HLO text →
//! rust PJRT runtime → S×K coordinator (L3): trains the `small` model
//! (100 234 params, B=194, CIFAR-shaped synthetic data) with the paper's
//! distributed method for several hundred iterations ON THE XLA BACKEND
//! through the unified `Session` API, logging the loss curve. Recorded in
//! EXPERIMENTS.md §E2E.
//!
//!     make artifacts && cargo run --release --example e2e_train
//!     (optional: SGS_E2E_ITERS=600 to override the iteration budget)

#[cfg(not(feature = "xla"))]
fn main() {
    eprintln!("e2e_train requires the `xla` feature (enabled by default);");
    eprintln!("rebuild without --no-default-features to run it.");
}

#[cfg(feature = "xla")]
fn main() -> Result<(), sgs::Error> {
    use std::sync::Arc;

    use sgs::config::{ExperimentConfig, ModelShape};
    use sgs::runtime::{ComputeBackend, XlaBackend};
    use sgs::session::Session;
    use sgs::trainer::LrSchedule;

    let iters: usize = std::env::var("SGS_E2E_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);

    println!("== e2e: loading AOT artifacts (HLO text -> PJRT) ==");
    let backend: Arc<dyn ComputeBackend> = Arc::new(XlaBackend::load("artifacts")?);
    println!(
        "backend: {} | {} layers | batch {}",
        backend.name(),
        backend.layers().len(),
        backend.batch()
    );

    let layers = backend.layers();
    let cfg = ExperimentConfig {
        name: "e2e".into(),
        model: ModelShape {
            d_in: layers[0].d_in,
            hidden: layers[0].d_out,
            blocks: layers.len() - 2,
            classes: layers.last().unwrap().d_out,
        }
        .into(),
        batch: backend.batch(),
        iters,
        lr: LrSchedule::strategy_2(iters),
        seed: 2026,
        eval_every: 25,
        ..ExperimentConfig::default()
    };
    println!(
        "config: S={} K={} topology={} iters={} lr={}",
        cfg.s,
        cfg.k,
        cfg.topology.name(),
        cfg.iters,
        cfg.lr.describe()
    );

    println!("building session (50k-sample synthetic CIFAR-like dataset,");
    println!("cost model calibrated on the XLA backend) ...");
    let session = Session::builder(cfg)
        .with_backend(backend)
        .calibrate_clock(true)
        .build()?;

    let t0 = std::time::Instant::now();
    let out = session.run_to_end()?;
    let wall = t0.elapsed().as_secs_f64();

    println!("\n   iter       lr   train-loss    eval-loss     acc        δ(t)");
    for r in &out.recorder.records {
        if r.eval_loss.is_some() {
            println!(
                "{:>7} {:>8.4} {:>12.4} {:>12.4} {:>6.1}% {:>11}",
                r.t,
                r.lr,
                r.train_loss.unwrap_or(f64::NAN),
                r.eval_loss.unwrap(),
                r.eval_acc.unwrap_or(f64::NAN) * 100.0,
                r.delta.map_or("-".into(), |d| format!("{d:.2e}")),
            );
        }
    }

    let s = out.recorder.summary();
    let first = out
        .recorder
        .records
        .iter()
        .find_map(|r| r.eval_loss)
        .unwrap_or(f64::NAN);
    println!("\n== e2e summary ==");
    println!("  eval loss: {:.4} -> {:.4}", first, s.final_eval_loss.unwrap_or(f64::NAN));
    println!("  accuracy:  {:.1}%", s.final_eval_acc.unwrap_or(f64::NAN) * 100.0);
    println!("  final δ:   {:.2e} (gamma {:.4})", out.final_delta, out.gamma);
    println!("  modelled iteration: {:.2} ms | wall {:.1}s for {} iters", out.iter_time_s * 1e3, wall, s.iters);
    out.recorder.write_csv("bench_out/e2e_train.csv")?;
    println!("  per-iteration CSV: bench_out/e2e_train.csv");

    if let (Some(final_eval), false) = (s.final_eval_loss, first.is_nan()) {
        assert!(
            final_eval < first,
            "E2E FAILED: eval loss did not improve ({first} -> {final_eval})"
        );
        println!("\nE2E OK: all three layers compose and the model learns.");
    }
    Ok(())
}
