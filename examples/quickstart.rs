//! Quickstart: train the paper's distributed method (S=4 data-groups,
//! K=2 pipeline modules, ring gossip) on the synthetic CIFAR-like task
//! with the pure-Rust backend — no artifacts needed.
//!
//!     cargo run --release --example quickstart

use sgs::config::{ExperimentConfig, ModelShape};
use sgs::coordinator::{build_dataset, run_with};
use sgs::graph::Topology;
use sgs::runtime::NativeBackend;
use sgs::simclock::CostModel;
use sgs::trainer::LrSchedule;

fn main() -> Result<(), sgs::Error> {
    let cfg = ExperimentConfig {
        name: "quickstart".into(),
        s: 4,
        k: 2,
        topology: Topology::Ring,
        alpha: None,
        gossip_rounds: 1,
        model: ModelShape { d_in: 64, hidden: 48, blocks: 3, classes: 10 },
        batch: 32,
        iters: 500,
        lr: LrSchedule::strategy_1(),
        optimizer: sgs::trainer::OptimizerKind::Sgd,
        mode: sgs::staleness::PipelineMode::FullyDecoupled,
        seed: 42,
        dataset_n: 4000,
        delta_every: 10,
        eval_every: 100,
    };

    println!("== sgs quickstart: S={} K={} on {} ==", cfg.s, cfg.k, cfg.topology.name());
    let ds = build_dataset(&cfg);
    let backend = NativeBackend::new(cfg.model.layers(), cfg.batch);
    let cm = CostModel::calibrate(&backend, 3);
    let out = run_with(cfg, &backend, &ds, Some(&cm))?;

    println!("gamma = {:.4} (consensus contraction, Lemma 2.1)", out.gamma);
    println!("modelled iteration time: {:.3} ms", out.iter_time_s * 1e3);
    println!("\n   iter   train-loss      δ(t)");
    for (t, loss, _) in out.recorder.loss_series(50, 25) {
        let delta = out
            .recorder
            .records
            .iter()
            .take(t + 1)
            .rev()
            .find_map(|r| r.delta);
        println!(
            "{t:>7} {loss:>12.4} {:>10}",
            delta.map_or("-".into(), |d| format!("{d:.2e}"))
        );
    }
    let s = out.recorder.summary();
    println!(
        "\nfinal: train {:.4}, eval {:.4}, accuracy {:.1}%, δ {:.2e}",
        s.final_train_loss.unwrap_or(f64::NAN),
        s.final_eval_loss.unwrap_or(f64::NAN),
        s.final_eval_acc.unwrap_or(f64::NAN) * 100.0,
        out.final_delta
    );
    Ok(())
}
