//! Quickstart: train the paper's distributed method (S=4 data-groups,
//! K=2 pipeline modules, ring gossip) on the synthetic CIFAR-like task
//! through the unified `Session` API — no artifacts needed, and the same
//! code drives either engine (`--threaded` for one thread per agent).
//!
//!     cargo run --release --example quickstart [-- --threaded]

use sgs::config::{ExperimentConfig, ModelShape};
use sgs::session::{EngineKind, Session};

fn main() -> Result<(), sgs::Error> {
    let engine = if std::env::args().any(|a| a == "--threaded") {
        EngineKind::Threaded
    } else {
        EngineKind::Sim
    };
    let cfg = ExperimentConfig {
        name: "quickstart".into(),
        model: ModelShape { d_in: 64, hidden: 48, blocks: 3, classes: 10 }.into(),
        batch: 32,
        iters: 500,
        seed: 42,
        dataset_n: 4000,
        eval_every: 100,
        ..ExperimentConfig::default()
    };

    println!(
        "== sgs quickstart: S={} K={} on {} ({} engine) ==",
        cfg.s,
        cfg.k,
        cfg.topology.name(),
        engine.as_str()
    );
    let mut session = Session::builder(cfg)
        .engine(engine)
        .calibrate_clock(true)
        .build()?;

    println!("gamma = {:.4} (consensus contraction, Lemma 2.1)", session.gamma());
    println!("modelled iteration time: {:.3} ms", session.iter_time_s() * 1e3);

    // stream iteration events: loss, δ(t), and per-module staleness
    println!("\n   iter   train-loss      δ(t)   staleness");
    let mut last_delta = None;
    session.run_streaming(|ev| {
        if let Some(d) = ev.delta {
            last_delta = Some(d);
        }
        if ev.t % 50 == 0 {
            println!(
                "{:>7} {:>12.4} {:>10} {:>10?}",
                ev.t,
                ev.train_loss.unwrap_or(f64::NAN),
                last_delta.map_or("-".into(), |d| format!("{d:.2e}")),
                ev.staleness
            );
        }
        Ok(())
    })?;

    let out = session.finish();
    let s = out.recorder.summary();
    println!(
        "\nfinal: train {:.4}, eval {:.4}, accuracy {:.1}%, δ {:.2e}",
        s.final_train_loss.unwrap_or(f64::NAN),
        s.final_eval_loss.unwrap_or(f64::NAN),
        s.final_eval_acc.unwrap_or(f64::NAN) * 100.0,
        out.final_delta
    );
    Ok(())
}
