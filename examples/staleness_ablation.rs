//! Staleness ablation: the cost of decoupling. Sweeping K at fixed S shows
//! the per-iteration latency win (max-module vs sum-of-layers) against the
//! accuracy cost of 2(K−1) iterations of gradient staleness at module 0 —
//! the trade-off Section 3.2 and Fig. 1 describe.
//!
//!     cargo run --release --example staleness_ablation

use std::sync::Arc;

use sgs::config::{ExperimentConfig, ModelShape};
use sgs::coordinator::build_dataset;
use sgs::graph::Topology;
use sgs::runtime::{ComputeBackend, NativeBackend};
use sgs::session::Session;
use sgs::simclock::CostModel;
use sgs::staleness::Schedule;
use sgs::trainer::LrSchedule;

fn main() -> Result<(), sgs::Error> {
    let base = ExperimentConfig {
        name: "staleness-ablation".into(),
        s: 2,
        k: 1,
        topology: Topology::Complete,
        // 6 layers so K in {1,2,3,6} partitions evenly
        model: ModelShape { d_in: 48, hidden: 32, blocks: 4, classes: 10 }.into(),
        batch: 24,
        iters: 600,
        lr: LrSchedule::Const(0.1),
        seed: 11,
        dataset_n: 8000,
        delta_every: 0,
        eval_every: 150,
        ..ExperimentConfig::default()
    };
    let ds = Arc::new(build_dataset(&base));
    let backend: Arc<dyn ComputeBackend> =
        Arc::new(NativeBackend::new(base.model.layers(), base.batch));
    let cm = CostModel::calibrate(backend.as_ref(), 3);

    println!(
        "{:>3} {:>12} {:>11} {:>10} {:>12} {:>12} {:>8}",
        "K", "staleness", "warmup", "iter(ms)", "train-loss", "eval-loss", "acc"
    );
    for k in [1usize, 2, 3, 6] {
        let sched = Schedule::new(k);
        let mut cfg = base.clone();
        cfg.k = k;
        let out = Session::builder(cfg)
            .with_backend(backend.clone())
            .dataset(ds.clone())
            .cost_model(&cm)
            .build()?
            .run_to_end()?;
        let s = out.recorder.summary();
        println!(
            "{:>3} {:>12} {:>11} {:>10.3} {:>12.4} {:>12.4} {:>7.1}%",
            k,
            format!("0..{}", sched.staleness(0)),
            sched.warmup_iters(),
            out.iter_time_s * 1e3,
            s.final_train_loss.unwrap_or(f64::NAN),
            s.final_eval_loss.unwrap_or(f64::NAN),
            s.final_eval_acc.unwrap_or(f64::NAN) * 100.0,
        );
    }
    println!("\nlatency shrinks ~1/K while staleness grows 2(K−1):");
    println!("the paper picks K=2 as the sweet spot (Section 5).");
    Ok(())
}
