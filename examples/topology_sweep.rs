//! Topology ablation: how the model-group gossip graph (Assumption 3.1.2)
//! shapes the consensus error δ(t) and the spectral gap γ.
//!
//!     cargo run --release --example topology_sweep

use std::sync::Arc;

use sgs::config::{ExperimentConfig, ModelShape};
use sgs::coordinator::{build_dataset, AgentGrid};
use sgs::graph::{mixing_time_estimate, Topology};
use sgs::runtime::{ComputeBackend, NativeBackend};
use sgs::session::Session;
use sgs::trainer::LrSchedule;

fn main() -> Result<(), sgs::Error> {
    let s = 8;
    let base = ExperimentConfig {
        name: "topology-sweep".into(),
        s,
        model: ModelShape { d_in: 48, hidden: 32, blocks: 2, classes: 10 }.into(),
        batch: 24,
        iters: 400,
        lr: LrSchedule::Const(0.1),
        seed: 3,
        dataset_n: 12_000,
        delta_every: 5,
        eval_every: 0,
        ..ExperimentConfig::default()
    };
    let ds = Arc::new(build_dataset(&base));
    let backend: Arc<dyn ComputeBackend> =
        Arc::new(NativeBackend::new(base.model.layers(), base.batch));

    println!("S = {s} data-groups, K = 2 modules; sweeping gossip topology\n");
    println!(
        "{:<12} {:>8} {:>10} {:>12} {:>12} {:>12}",
        "topology", "edges", "gamma", "mix(x100)", "final loss", "δ floor"
    );
    for topo in [
        Topology::Line,
        Topology::Ring,
        Topology::Star,
        Topology::Torus { rows: 2, cols: 4 },
        Topology::Complete,
    ] {
        let grid = AgentGrid::build(s, 1, topo, None)?;
        let mut cfg = base.clone();
        cfg.topology = topo;
        let out = Session::builder(cfg)
            .with_backend(backend.clone())
            .dataset(ds.clone())
            .build()?
            .run_to_end()?;
        let deltas: Vec<f64> = out
            .recorder
            .records
            .iter()
            .rev()
            .filter_map(|r| r.delta)
            .take(20)
            .collect();
        let floor = deltas.iter().sum::<f64>() / deltas.len().max(1) as f64;
        println!(
            "{:<12} {:>8} {:>10.4} {:>12} {:>12.4} {:>12.2e}",
            topo.name(),
            grid.model_graph.edge_count(),
            out.gamma,
            mixing_time_estimate(out.gamma, 100.0),
            out.recorder.summary().final_train_loss.unwrap_or(f64::NAN),
            floor
        );
    }
    println!("\ndenser graphs -> smaller gamma -> tighter consensus (Lemma 4.4).");
    Ok(())
}
