//! The Section-5 experiment at example scale: the paper's four methods —
//! centralized (1,1), decoupled (1,2), data-parallel (4,1), distributed
//! (4,2) — on one shared dataset through the unified `Session` API,
//! printing the comparison table Fig. 3 summarizes. Native backend for
//! speed; `benches/fig3.rs` is the full figure generator.
//!
//!     cargo run --release --example four_methods

use std::sync::Arc;

use sgs::config::{ExperimentConfig, ModelShape};
use sgs::coordinator::build_dataset;
use sgs::runtime::{ComputeBackend, NativeBackend};
use sgs::session::Session;
use sgs::simclock::CostModel;

fn main() -> Result<(), sgs::Error> {
    let base = ExperimentConfig {
        name: "four-methods".into(),
        model: ModelShape { d_in: 64, hidden: 48, blocks: 3, classes: 10 }.into(),
        batch: 32,
        iters: 800,
        seed: 7,
        dataset_n: 8000,
        delta_every: 20,
        eval_every: 200,
        ..ExperimentConfig::default()
    };
    let ds = Arc::new(build_dataset(&base));
    let backend: Arc<dyn ComputeBackend> =
        Arc::new(NativeBackend::new(base.model.layers(), base.batch));
    let cm = CostModel::calibrate(backend.as_ref(), 3);

    println!(
        "{:<16} {:>3} {:>3} {:>11} {:>12} {:>12} {:>8} {:>10}",
        "method", "S", "K", "iter(ms)", "train-loss", "eval-loss", "acc", "δ(t)"
    );
    let mut rows = Vec::new();
    for (label, cfg) in ExperimentConfig::paper_methods(&base) {
        let out = Session::builder(cfg.clone())
            .with_backend(backend.clone())
            .dataset(ds.clone())
            .cost_model(&cm)
            .build()?
            .run_to_end()?;
        let s = out.recorder.summary();
        println!(
            "{:<16} {:>3} {:>3} {:>11.3} {:>12.4} {:>12.4} {:>7.1}% {:>10.2e}",
            label,
            cfg.s,
            cfg.k,
            out.iter_time_s * 1e3,
            s.final_train_loss.unwrap_or(f64::NAN),
            s.final_eval_loss.unwrap_or(f64::NAN),
            s.final_eval_acc.unwrap_or(f64::NAN) * 100.0,
            out.final_delta
        );
        rows.push((label, out));
    }

    // the paper's two headline observations:
    let iter_ms =
        |label: &str| rows.iter().find(|(l, _)| *l == label).unwrap().1.iter_time_s * 1e3;
    println!(
        "\npipeline speedup (per-batch latency, paper: 85ms -> 58ms ≈ 1.47x): {:.2}x",
        iter_ms("centralized") / iter_ms("decoupled")
    );
    println!(
        "distributed vs centralized per-iteration latency: {:.2}x",
        iter_ms("centralized") / iter_ms("distributed")
    );
    Ok(())
}
